//! Per-partition feature servers: the remote end of the fetch RPC.
//!
//! Each partition gets one serving loop owning its feature shard — the
//! partition's rows materialized once at spawn as a seeded, resident
//! tensor ([`FeatureShard`]), so serving is a row copy, not a per-request
//! re-synthesis.  It decodes [`Frame::FetchReq`] frames, gathers the
//! requested rows, optionally emulates the fabric's α–β transfer time at a
//! configurable wall-clock scale, and replies with a serialized
//! [`Frame::FetchResp`] on the requesting trainer's reply link.  The loop
//! is transport-agnostic: its inbox is a [`NetMsg`] channel fed either
//! directly by in-process prefetchers (channel transport) or by the
//! accept/pump threads of a TCP listener, and reply routes arrive either
//! pre-registered (channel) or via [`NetMsg::Register`] handshakes (TCP).
//! The loop exits when every request source has hung up.

use std::sync::mpsc::{Receiver, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::graph::features::fill_features;
use crate::net::Network;
use crate::partition::Partition;
use crate::trace::{EventKind, Role, TraceEvent, Tracer};
use crate::util::fasthash::FastMap;

use super::transport::{FaultSender, FaultSpec, FrameSender, NetMsg};
use super::wire::Frame;

/// Traffic served by one feature server.
#[derive(Debug, Clone, Default)]
pub struct ServerStats {
    pub part: usize,
    pub requests: u64,
    pub nodes_served: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
    /// Frames that failed to decode, had an unexpected kind, or named an
    /// unknown reply route.
    pub bad_frames: u64,
}

/// Wall-clock emulation of the RPC fabric, derived from the same α–β
/// [`crate::net::NetParams`] the virtual-time sim charges: each reply is
/// delayed by `scale × (α + β·bytes·contention)`.  `scale = 0` disables
/// emulation (as fast as the hardware allows).
#[derive(Debug, Clone, Copy)]
pub struct WireDelay {
    pub alpha: f64,
    pub beta_contended: f64,
    pub scale: f64,
}

impl WireDelay {
    pub fn from_net(net: &Network, scale: f64) -> WireDelay {
        WireDelay {
            alpha: net.params.alpha,
            beta_contended: net.params.beta * net.contention_factor(),
            scale,
        }
    }

    /// Sleep for the emulated transfer time of a `bytes`-sized payload.
    pub fn emulate(&self, bytes: usize) {
        if self.scale <= 0.0 {
            return;
        }
        let secs = self.scale * (self.alpha + self.beta_contended * bytes as f64);
        if secs > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(secs));
        }
    }
}

/// Partition-resident feature shard: every owned node's feature row
/// materialized once (row-major block plus an id → row index), exactly as
/// a real feature server would hold its partition's slice of the feature
/// matrix in memory.  Values are identical to on-demand synthesis —
/// features are a pure function of `(seed, node)` — so the wire payloads
/// are unchanged; only the serving cost moves from hashing to a copy.
pub(crate) struct FeatureShard {
    feat_dim: usize,
    feature_seed: u64,
    index: FastMap<u32, u32>,
    rows: Vec<f32>,
}

impl FeatureShard {
    pub(crate) fn build(
        part: &Partition,
        part_id: usize,
        feature_seed: u64,
        feat_dim: usize,
    ) -> FeatureShard {
        let owned = &part.local_nodes[part_id];
        let mut index = FastMap::default();
        let mut rows = vec![0.0f32; owned.len() * feat_dim];
        for (i, &n) in owned.iter().enumerate() {
            index.insert(n, i as u32);
            fill_features(feature_seed, n, &mut rows[i * feat_dim..(i + 1) * feat_dim]);
        }
        FeatureShard { feat_dim, feature_seed, index, rows }
    }

    /// Copy node `n`'s row into `dst`.  A non-resident node (impossible
    /// under owner routing) falls back to synthesis so the payload stays
    /// correct either way.
    pub(crate) fn fill(&self, n: u32, dst: &mut [f32]) {
        match self.index.get(&n) {
            Some(&i) => {
                let i = i as usize;
                dst.copy_from_slice(&self.rows[i * self.feat_dim..(i + 1) * self.feat_dim]);
            }
            None => fill_features(self.feature_seed, n, dst),
        }
    }
}

/// Wrap a reply link with the fault-injection shim when configured.  The
/// schedule seed is derived per (server, trainer) link so every link draws
/// an independent, reproducible fault sequence.
fn wrap_fault(
    sender: Box<dyn FrameSender>,
    fault: &Option<FaultSpec>,
    part_id: usize,
    trainer_id: u32,
) -> Box<dyn FrameSender> {
    match fault {
        Some(spec) => Box::new(FaultSender::new(
            sender,
            spec,
            &[part_id as u64, trainer_id as u64],
        )),
        None => sender,
    }
}

/// The serving loop for partition `part_id`.  `prereg` carries reply links
/// known at spawn time (channel transport); socket transports register
/// theirs through [`NetMsg::Register`] before any frame from that peer
/// arrives.  Runs until `rx` disconnects; used inline by the TCP worker
/// process and on a thread by [`spawn_server`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn server_loop(
    part_id: usize,
    feature_seed: u64,
    feat_dim: usize,
    part: Arc<Partition>,
    rx: Receiver<NetMsg>,
    prereg: Vec<(u32, Box<dyn FrameSender>)>,
    delay: WireDelay,
    fault: Option<FaultSpec>,
    trace: bool,
) -> (ServerStats, Vec<TraceEvent>) {
    let mut stats = ServerStats { part: part_id, ..ServerStats::default() };
    let mut tracer = Tracer::new(trace, Role::Server, part_id as u32);
    let shard = FeatureShard::build(&part, part_id, feature_seed, feat_dim);
    let mut replies: FastMap<u32, Box<dyn FrameSender>> = FastMap::default();
    for (id, s) in prereg {
        replies.insert(id, wrap_fault(s, &fault, part_id, id));
    }
    loop {
        // Drain eagerly; on an empty inbox flush fault-held replies before
        // blocking, so an injected delay re-orders frames but can never
        // stall a trainer that is blocked waiting on the held response.
        let msg = match rx.try_recv() {
            Ok(m) => m,
            Err(TryRecvError::Disconnected) => break,
            Err(TryRecvError::Empty) => {
                for r in replies.values_mut() {
                    r.flush_pending();
                }
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => break,
                }
            }
        };
        let bytes = match msg {
            NetMsg::Register(id, s) => {
                replies.insert(id, wrap_fault(s, &fault, part_id, id));
                continue;
            }
            NetMsg::Frame(bytes) => bytes,
        };
        stats.bytes_in += bytes.len() as u64;
        let (frame, _) = match Frame::decode(&bytes) {
            Ok(ok) => ok,
            Err(_) => {
                stats.bad_frames += 1;
                continue;
            }
        };
        let Frame::FetchReq { req_id, from, nodes } = frame else {
            stats.bad_frames += 1;
            continue;
        };
        let Some(reply) = replies.get_mut(&from) else {
            stats.bad_frames += 1;
            continue;
        };
        debug_assert!(
            nodes.iter().all(|&n| part.owner_of(n) == part_id),
            "fetch routed to non-owner partition {part_id}"
        );
        let mut feats = vec![0.0f32; nodes.len() * feat_dim];
        for (i, &n) in nodes.iter().enumerate() {
            shard.fill(n, &mut feats[i * feat_dim..(i + 1) * feat_dim]);
        }
        stats.requests += 1;
        stats.nodes_served += nodes.len() as u64;
        let served = nodes.len() as u64;
        let out = Frame::FetchResp { req_id, feat_dim: feat_dim as u32, nodes, feats }.encode();
        stats.bytes_out += out.len() as u64;
        tracer.emit(
            0.0,
            EventKind::FetchServe { req_id, from, nodes: served, bytes: out.len() as u64 },
        );
        delay.emulate(out.len());
        // Prefetcher gone (trainer already finished): drop reply.
        let _ = reply.send_frame(&out);
    }
    // Reply links drop here, flushing any fault-shim-held frames while the
    // peers' drain loops are still reading.
    (stats, tracer.finish())
}

/// Spawn [`server_loop`] on its own OS thread.
#[allow(clippy::too_many_arguments)]
pub(crate) fn spawn_server(
    part_id: usize,
    feature_seed: u64,
    feat_dim: usize,
    part: Arc<Partition>,
    rx: Receiver<NetMsg>,
    prereg: Vec<(u32, Box<dyn FrameSender>)>,
    delay: WireDelay,
    fault: Option<FaultSpec>,
    trace: bool,
) -> JoinHandle<(ServerStats, Vec<TraceEvent>)> {
    std::thread::Builder::new()
        .name(format!("rudder-server-{part_id}"))
        .spawn(move || {
            server_loop(part_id, feature_seed, feat_dim, part, rx, prereg, delay, fault, trace)
        })
        .expect("spawn feature-server thread")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::rmat::{generate, RmatParams};
    use crate::net::NetParams;
    use crate::partition::{partition, Method};
    use crate::util::rng::Pcg32;
    use std::sync::mpsc;

    use crate::cluster::prefetch::PrefetchMsg;
    use crate::cluster::transport::{ChannelSender, LinkStatsHandle};

    #[test]
    fn serves_owned_nodes_with_correct_features() {
        let csr = generate(
            &RmatParams {
                a: 0.57,
                b: 0.19,
                c: 0.19,
                num_nodes: 400,
                num_edges: 2400,
                permute: true,
            },
            &mut Pcg32::new(5),
        );
        let part = Arc::new(partition(&csr, 2, Method::MetisLike, 1));
        let (req_tx, req_rx) = mpsc::channel::<NetMsg>();
        let (rep_tx, rep_rx) = mpsc::channel::<PrefetchMsg>();
        let delay = WireDelay::from_net(&Network::new(NetParams::default(), 2), 0.0);
        let owned: Vec<u32> = part.local_nodes[0][..3].to_vec();
        let link = LinkStatsHandle::new("server:0");
        let prereg: Vec<(u32, Box<dyn FrameSender>)> = vec![(
            1,
            Box::new(ChannelSender::delivering(rep_tx, PrefetchMsg::Wire, link.clone())),
        )];
        let handle = spawn_server(0, 42, 4, part.clone(), req_rx, prereg, delay, None, true);
        req_tx
            .send(NetMsg::Frame(
                Frame::FetchReq { req_id: 9, from: 1, nodes: owned.clone() }.encode(),
            ))
            .unwrap();
        let PrefetchMsg::Wire(resp) = rep_rx.recv().unwrap() else {
            panic!("expected wire reply")
        };
        let (frame, _) = Frame::decode(&resp).unwrap();
        let Frame::FetchResp { req_id, feat_dim, nodes, feats } = frame else {
            panic!("expected FetchResp")
        };
        assert_eq!((req_id, feat_dim), (9, 4));
        assert_eq!(nodes, owned);
        let mut want = vec![0.0f32; 4];
        crate::graph::features::fill_features(42, owned[1], &mut want);
        assert_eq!(&feats[4..8], &want[..], "row 1 must be node {}'s features", owned[1]);
        drop(req_tx);
        let (stats, trace) = handle.join().unwrap();
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.nodes_served, 3);
        assert!(stats.bytes_out > stats.bytes_in);
        // One FetchServe event plus the terminal RoleEnd.
        assert_eq!(trace.len(), 2);
        assert!(matches!(
            trace[0].kind,
            EventKind::FetchServe { req_id: 9, from: 1, nodes: 3, .. }
        ));
        // Reply delivery counted as received on the trainer-side link.
        let snap = link.snapshot();
        assert_eq!(snap.frames_recv, 1);
    }

    #[test]
    fn feature_shard_serves_resident_copies() {
        let csr = generate(
            &RmatParams {
                a: 0.57,
                b: 0.19,
                c: 0.19,
                num_nodes: 300,
                num_edges: 1800,
                permute: true,
            },
            &mut Pcg32::new(9),
        );
        let part = partition(&csr, 2, Method::MetisLike, 1);
        let shard = FeatureShard::build(&part, 0, 11, 4);
        assert_eq!(shard.index.len(), part.local_nodes[0].len());
        let mut got = vec![0.0f32; 4];
        let mut want = vec![0.0f32; 4];
        // Resident row: a copy of the materialized tensor, bit-identical
        // to synthesis.
        let own = part.local_nodes[0][0];
        shard.fill(own, &mut got);
        fill_features(11, own, &mut want);
        assert_eq!(got, want);
        // Foreign node: synthesis fallback, same values.
        let foreign = part.local_nodes[1][0];
        shard.fill(foreign, &mut got);
        fill_features(11, foreign, &mut want);
        assert_eq!(got, want);
    }

    #[test]
    fn faulted_reply_link_duplicates_responses() {
        let csr = generate(
            &RmatParams {
                a: 0.57,
                b: 0.19,
                c: 0.19,
                num_nodes: 200,
                num_edges: 1200,
                permute: true,
            },
            &mut Pcg32::new(6),
        );
        let part = Arc::new(partition(&csr, 1, Method::MetisLike, 1));
        let (req_tx, req_rx) = mpsc::channel::<NetMsg>();
        let (rep_tx, rep_rx) = mpsc::channel::<PrefetchMsg>();
        let delay = WireDelay::from_net(&Network::new(NetParams::default(), 1), 0.0);
        let fault = FaultSpec { seed: 5, dup: 1.0, delay: 0.0, chop: 0 };
        let link = LinkStatsHandle::new("server:0");
        let prereg: Vec<(u32, Box<dyn FrameSender>)> = vec![(
            0,
            Box::new(ChannelSender::delivering(rep_tx, PrefetchMsg::Wire, link)),
        )];
        let owned: Vec<u32> = part.local_nodes[0][..2].to_vec();
        let handle = spawn_server(0, 1, 2, part, req_rx, prereg, delay, Some(fault), false);
        req_tx
            .send(NetMsg::Frame(Frame::FetchReq { req_id: 0, from: 0, nodes: owned }.encode()))
            .unwrap();
        drop(req_tx);
        let (stats, trace) = handle.join().unwrap();
        assert!(trace.is_empty(), "tracing disabled");
        assert_eq!(stats.requests, 1, "server serves each request once");
        let mut replies = 0;
        while let Ok(PrefetchMsg::Wire(_)) = rep_rx.recv() {
            replies += 1;
        }
        assert_eq!(replies, 2, "dup=1.0 must deliver every response twice");
    }
}
