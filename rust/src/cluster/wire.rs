//! Wire format for the in-process cluster runtime.
//!
//! Every message between trainer, prefetcher, feature server, and the
//! allreduce hub crosses its channel as a *serialized frame* — a
//! length-prefixed byte buffer, never a shared reference — so the RPC path
//! pays honest encode/decode cost and the protocol could move to a socket
//! unchanged.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! [u32 body_len][u8 kind][kind-specific payload]
//! ```
//!
//! Vectors are encoded as `[u32 count][elements...]`.  Decoding validates
//! the kind byte, every length against the remaining bytes (truncated
//! frames are rejected, never panicked on), cross-field consistency
//! (`feats.len() == nodes.len() × feat_dim`), and that the body is fully
//! consumed (no trailing bytes).  Encoding is fallible for the same
//! reason: a vector longer than `u32::MAX` elements or a body over
//! [`MAX_FRAME_BYTES`] is rejected with an error instead of silently
//! wrapping the length field.
//!
//! Kinds 1–6 are the v1 row protocol; kinds 7–8 carry the
//! content-addressed chunk protocol ([`Frame::ChunkReq`] /
//! [`Frame::ChunkResp`]) used when the per-link chunk cache is enabled.

use crate::error::Result;

/// Frame kind tags (the `u8` after the length prefix).
const KIND_FETCH_REQ: u8 = 1;
const KIND_FETCH_RESP: u8 = 2;
const KIND_ALLREDUCE: u8 = 3;
const KIND_HELLO: u8 = 4;
const KIND_RESULT: u8 = 5;
const KIND_CONFIG: u8 = 6;
const KIND_CHUNK_REQ: u8 = 7;
const KIND_CHUNK_RESP: u8 = 8;

/// `Frame::Hello` / `Frame::Result` role tags: who is announcing itself
/// on a fresh transport connection, or whose result a blob carries.
pub const ROLE_TRAINER: u8 = 1;
pub const ROLE_SERVER: u8 = 2;
pub const ROLE_HUB: u8 = 3;

/// Upper bound on a frame body; anything larger is rejected as malformed
/// before any allocation happens (and rejected at encode time before it
/// can hit a link).
pub const MAX_FRAME_BYTES: usize = 1 << 28;

/// One content-addressed feature chunk on the wire: the FNV-1a digest of
/// the row bytes, the global node ids of the rows (in owner-partition
/// local order), and the row-major feature payload
/// (`nodes.len() × feat_dim`).
#[derive(Debug, Clone, PartialEq)]
pub struct Chunk {
    pub digest: u64,
    pub nodes: Vec<u32>,
    pub feats: Vec<f32>,
}

/// One RPC message of the cluster protocol.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Trainer `from` asks a feature server for `nodes`' features.
    FetchReq { req_id: u64, from: u32, nodes: Vec<u32> },
    /// Server reply: `feats` is row-major `[nodes.len() × feat_dim]`.
    FetchResp { req_id: u64, feat_dim: u32, nodes: Vec<u32>, feats: Vec<f32> },
    /// DDP gradient sync: trainer → hub carries the local gradient shard
    /// and the trainer's virtual clock; hub → trainer carries the reduced
    /// gradients and the barrier-wide max clock.
    Allreduce { part: u32, round: u64, vclock: f64, grads: Vec<f32> },
    /// Connection handshake (socket transports): the first frame on a
    /// fresh connection announces who dialed, so listeners can index the
    /// reply route.  The in-process channel transport never sends it.
    Hello { role: u8, id: u32 },
    /// A worker's final result returned over the wire: `blob` is an
    /// [`super::ipc`] result blob, `role`/`id` identify the worker
    /// (`ROLE_TRAINER`/`ROLE_SERVER` + part index, or `ROLE_HUB`).  Sent
    /// once on a fresh connection to the orchestrator's results listener,
    /// replacing the shared-filesystem `--out` blob files.
    Result { role: u8, id: u32, blob: Vec<u8> },
    /// The orchestrator's fully-resolved run config as TOML bytes, served
    /// over the control link in reply to a worker's `Hello` — so
    /// multi-process workers need no shared filesystem for `--run-config`.
    Config { toml: Vec<u8> },
    /// Digest-aware fetch (chunk protocol): trainer `from` asks for
    /// `nodes`' features and declares the digests of chunks it already
    /// holds, so the server can answer with only the chunks it lacks.
    ChunkReq { req_id: u64, from: u32, nodes: Vec<u32>, have: Vec<u64> },
    /// Chunked server reply: whole chunks covering the requested nodes.
    /// `refs` lists the digests the server *elided* because the trainer
    /// declared them in `have` — the idempotent re-fetch path.
    ChunkResp { req_id: u64, feat_dim: u32, refs: Vec<u64>, chunks: Vec<Chunk> },
}

/// Checked `usize → u32` for vector length fields: a count that does not
/// fit the wire's `u32` is an encode-time error, never a silent wrap.
/// Shared with the [`super::ipc`] and [`crate::trace::codec`] encoders so
/// every codec narrows through one checked path (the
/// `unchecked-narrowing-in-codec` audit rule pins this).
pub(crate) fn len_u32(n: usize, what: &str) -> Result<u32> {
    u32::try_from(n).map_err(|_| crate::err!("wire: {what} length {n} exceeds u32 on encode"))
}

impl Frame {
    /// Serialize to a length-prefixed byte buffer.
    ///
    /// Fails (instead of corrupting the stream) if any vector length
    /// overflows its `u32` field or the body exceeds [`MAX_FRAME_BYTES`].
    pub fn encode(&self) -> Result<Vec<u8>> {
        let mut body = Vec::with_capacity(64);
        match self {
            Frame::FetchReq { req_id, from, nodes } => {
                body.push(KIND_FETCH_REQ);
                put_u64(&mut body, *req_id);
                put_u32(&mut body, *from);
                put_u32(&mut body, len_u32(nodes.len(), "FetchReq nodes")?);
                for &n in nodes {
                    put_u32(&mut body, n);
                }
            }
            Frame::FetchResp { req_id, feat_dim, nodes, feats } => {
                body.push(KIND_FETCH_RESP);
                put_u64(&mut body, *req_id);
                put_u32(&mut body, *feat_dim);
                put_u32(&mut body, len_u32(nodes.len(), "FetchResp nodes")?);
                for &n in nodes {
                    put_u32(&mut body, n);
                }
                put_u32(&mut body, len_u32(feats.len(), "FetchResp feats")?);
                for &f in feats {
                    body.extend_from_slice(&f.to_le_bytes());
                }
            }
            Frame::Allreduce { part, round, vclock, grads } => {
                body.push(KIND_ALLREDUCE);
                put_u32(&mut body, *part);
                put_u64(&mut body, *round);
                body.extend_from_slice(&vclock.to_le_bytes());
                put_u32(&mut body, len_u32(grads.len(), "Allreduce grads")?);
                for &g in grads {
                    body.extend_from_slice(&g.to_le_bytes());
                }
            }
            Frame::Hello { role, id } => {
                body.push(KIND_HELLO);
                body.push(*role);
                put_u32(&mut body, *id);
            }
            Frame::Result { role, id, blob } => {
                body.push(KIND_RESULT);
                body.push(*role);
                put_u32(&mut body, *id);
                put_u32(&mut body, len_u32(blob.len(), "Result blob")?);
                body.extend_from_slice(blob);
            }
            Frame::Config { toml } => {
                body.push(KIND_CONFIG);
                put_u32(&mut body, len_u32(toml.len(), "Config toml")?);
                body.extend_from_slice(toml);
            }
            Frame::ChunkReq { req_id, from, nodes, have } => {
                body.push(KIND_CHUNK_REQ);
                put_u64(&mut body, *req_id);
                put_u32(&mut body, *from);
                put_u32(&mut body, len_u32(nodes.len(), "ChunkReq nodes")?);
                for &n in nodes {
                    put_u32(&mut body, n);
                }
                put_u32(&mut body, len_u32(have.len(), "ChunkReq have")?);
                for &d in have {
                    put_u64(&mut body, d);
                }
            }
            Frame::ChunkResp { req_id, feat_dim, refs, chunks } => {
                body.push(KIND_CHUNK_RESP);
                put_u64(&mut body, *req_id);
                put_u32(&mut body, *feat_dim);
                put_u32(&mut body, len_u32(refs.len(), "ChunkResp refs")?);
                for &d in refs {
                    put_u64(&mut body, d);
                }
                put_u32(&mut body, len_u32(chunks.len(), "ChunkResp chunks")?);
                for c in chunks {
                    put_u64(&mut body, c.digest);
                    put_u32(&mut body, len_u32(c.nodes.len(), "Chunk nodes")?);
                    for &n in &c.nodes {
                        put_u32(&mut body, n);
                    }
                    put_u32(&mut body, len_u32(c.feats.len(), "Chunk feats")?);
                    for &f in &c.feats {
                        body.extend_from_slice(&f.to_le_bytes());
                    }
                }
            }
        }
        crate::ensure!(
            body.len() <= MAX_FRAME_BYTES,
            "wire: frame body {} exceeds cap {MAX_FRAME_BYTES} on encode",
            body.len()
        );
        let mut out = Vec::with_capacity(4 + body.len());
        out.extend_from_slice(&len_u32(body.len(), "frame body")?.to_le_bytes());
        out.extend_from_slice(&body);
        Ok(out)
    }

    /// Parse one frame from the start of `buf`; returns the frame and the
    /// total bytes consumed (prefix + body).  Rejects truncated input,
    /// unknown kinds, inconsistent lengths, and trailing body bytes.
    pub fn decode(buf: &[u8]) -> Result<(Frame, usize)> {
        crate::ensure!(buf.len() >= 4, "wire: truncated length prefix");
        let body_len = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]) as usize;
        crate::ensure!(body_len >= 1, "wire: empty frame body");
        crate::ensure!(body_len <= MAX_FRAME_BYTES, "wire: frame body {body_len} exceeds cap");
        crate::ensure!(
            buf.len() >= 4 + body_len,
            "wire: truncated frame (need {body_len} body bytes, have {})",
            buf.len() - 4
        );
        let mut r = Reader { b: &buf[4..4 + body_len], pos: 0 };
        let kind = r.u8()?;
        let frame = match kind {
            KIND_FETCH_REQ => {
                let req_id = r.u64()?;
                let from = r.u32()?;
                let nodes = r.vec_u32()?;
                Frame::FetchReq { req_id, from, nodes }
            }
            KIND_FETCH_RESP => {
                let req_id = r.u64()?;
                let feat_dim = r.u32()?;
                let nodes = r.vec_u32()?;
                let feats = r.vec_f32()?;
                crate::ensure!(
                    feats.len() == nodes.len() * feat_dim as usize,
                    "wire: FetchResp payload mismatch ({} feats for {} nodes × dim {feat_dim})",
                    feats.len(),
                    nodes.len()
                );
                Frame::FetchResp { req_id, feat_dim, nodes, feats }
            }
            KIND_ALLREDUCE => {
                let part = r.u32()?;
                let round = r.u64()?;
                let vclock = r.f64()?;
                let grads = r.vec_f32()?;
                Frame::Allreduce { part, round, vclock, grads }
            }
            KIND_HELLO => {
                let role = r.u8()?;
                let id = r.u32()?;
                Frame::Hello { role, id }
            }
            KIND_RESULT => {
                let role = r.u8()?;
                let id = r.u32()?;
                let len = r.u32()? as usize;
                let blob = r.take(len)?.to_vec();
                Frame::Result { role, id, blob }
            }
            KIND_CONFIG => {
                let len = r.u32()? as usize;
                let toml = r.take(len)?.to_vec();
                Frame::Config { toml }
            }
            KIND_CHUNK_REQ => {
                let req_id = r.u64()?;
                let from = r.u32()?;
                let nodes = r.vec_u32()?;
                let have = r.vec_u64()?;
                Frame::ChunkReq { req_id, from, nodes, have }
            }
            KIND_CHUNK_RESP => {
                let req_id = r.u64()?;
                let feat_dim = r.u32()?;
                let refs = r.vec_u64()?;
                let n_chunks = r.u32()? as usize;
                // Each chunk carries at least digest + two counts.
                crate::ensure!(
                    n_chunks <= r.remaining() / 16,
                    "wire: ChunkResp chunk count {n_chunks} exceeds frame body"
                );
                let mut chunks = Vec::with_capacity(n_chunks);
                for _ in 0..n_chunks {
                    let digest = r.u64()?;
                    let nodes = r.vec_u32()?;
                    let feats = r.vec_f32()?;
                    crate::ensure!(
                        feats.len() == nodes.len() * feat_dim as usize,
                        "wire: Chunk payload mismatch ({} feats for {} nodes × dim {feat_dim})",
                        feats.len(),
                        nodes.len()
                    );
                    chunks.push(Chunk { digest, nodes, feats });
                }
                Frame::ChunkResp { req_id, feat_dim, refs, chunks }
            }
            other => crate::bail!("wire: unknown frame kind {other}"),
        };
        crate::ensure!(
            r.pos == body_len,
            "wire: {} trailing bytes in frame body",
            body_len - r.pos
        );
        Ok((frame, 4 + body_len))
    }

    /// Payload size on the wire (what the byte counters record).
    pub fn encoded_len(&self) -> usize {
        // Cheap arithmetic mirror of `encode` (no allocation).
        4 + 1
            + match self {
                Frame::FetchReq { nodes, .. } => 8 + 4 + 4 + 4 * nodes.len(),
                Frame::FetchResp { nodes, feats, .. } => {
                    8 + 4 + 4 + 4 * nodes.len() + 4 + 4 * feats.len()
                }
                Frame::Allreduce { grads, .. } => 4 + 8 + 8 + 4 + 4 * grads.len(),
                Frame::Hello { .. } => 1 + 4,
                Frame::Result { blob, .. } => 1 + 4 + 4 + blob.len(),
                Frame::Config { toml } => 4 + toml.len(),
                Frame::ChunkReq { nodes, have, .. } => {
                    8 + 4 + 4 + 4 * nodes.len() + 4 + 8 * have.len()
                }
                Frame::ChunkResp { refs, chunks, .. } => {
                    8 + 4
                        + 4
                        + 8 * refs.len()
                        + 4
                        + chunks
                            .iter()
                            .map(|c| 8 + 4 + 4 * c.nodes.len() + 4 + 4 * c.feats.len())
                            .sum::<usize>()
                }
            }
    }
}

pub(crate) fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Bounds-checked cursor over a frame body (shared with the result-blob
/// codec in [`super::ipc`]).
pub(crate) struct Reader<'a> {
    pub(crate) b: &'a [u8],
    pub(crate) pos: usize,
}

impl<'a> Reader<'a> {
    pub(crate) fn new(b: &'a [u8]) -> Reader<'a> {
        Reader { b, pos: 0 }
    }

    pub(crate) fn remaining(&self) -> usize {
        self.b.len() - self.pos
    }

    pub(crate) fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        crate::ensure!(
            self.pos + n <= self.b.len(),
            "wire: frame body truncated (need {n} bytes at offset {})",
            self.pos
        );
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub(crate) fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    pub(crate) fn u64(&mut self) -> Result<u64> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]))
    }

    pub(crate) fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn vec_u32(&mut self) -> Result<Vec<u32>> {
        let count = self.u32()? as usize;
        // Validate before allocating: each element is 4 bytes.
        crate::ensure!(
            count <= (self.b.len() - self.pos) / 4,
            "wire: u32 vector length {count} exceeds frame body"
        );
        let mut v = Vec::with_capacity(count);
        for _ in 0..count {
            v.push(self.u32()?);
        }
        Ok(v)
    }

    fn vec_u64(&mut self) -> Result<Vec<u64>> {
        let count = self.u32()? as usize;
        crate::ensure!(
            count <= (self.b.len() - self.pos) / 8,
            "wire: u64 vector length {count} exceeds frame body"
        );
        let mut v = Vec::with_capacity(count);
        for _ in 0..count {
            v.push(self.u64()?);
        }
        Ok(v)
    }

    fn vec_f32(&mut self) -> Result<Vec<f32>> {
        let count = self.u32()? as usize;
        crate::ensure!(
            count <= (self.b.len() - self.pos) / 4,
            "wire: f32 vector length {count} exceeds frame body"
        );
        let mut v = Vec::with_capacity(count);
        for _ in 0..count {
            let s = self.take(4)?;
            v.push(f32::from_le_bytes([s[0], s[1], s[2], s[3]]));
        }
        Ok(v)
    }
}

// The adversarial suite (truncation at every cut, unknown kinds, oversized
// vector counts, payload mismatches, encode-rejects-oversize) lives in
// `tests/wire.rs` — one place, so codec changes update coverage once.
// This module keeps only a round-trip smoke for unit-test granularity.
#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)] // test code: panics are the failure report

    use super::*;

    #[test]
    fn roundtrip_all_kinds() {
        let frames = [
            Frame::FetchReq { req_id: 7, from: 2, nodes: vec![1, 9, 1 << 30] },
            Frame::FetchResp {
                req_id: 7,
                feat_dim: 2,
                nodes: vec![1, 9],
                feats: vec![0.5, -1.0, 3.25, f32::MIN],
            },
            Frame::Allreduce { part: 0, round: 41, vclock: 1.5e3, grads: vec![0.0; 5] },
            Frame::Hello { role: ROLE_TRAINER, id: 3 },
            Frame::Result { role: ROLE_SERVER, id: 2, blob: vec![0xAB, 0, 0xCD, 255] },
            Frame::Config { toml: b"dataset = \"products\"\n".to_vec() },
            Frame::ChunkReq { req_id: 11, from: 1, nodes: vec![4, 6], have: vec![0, u64::MAX] },
            Frame::ChunkResp {
                req_id: 11,
                feat_dim: 2,
                refs: vec![0xDEAD_BEEF],
                chunks: vec![
                    Chunk { digest: 42, nodes: vec![4, 6], feats: vec![1.0, 2.0, 3.0, 4.0] },
                    Chunk { digest: 7, nodes: vec![], feats: vec![] },
                ],
            },
        ];
        for f in frames {
            let bytes = f.encode().unwrap();
            assert_eq!(bytes.len(), f.encoded_len());
            let (back, used) = Frame::decode(&bytes).unwrap();
            assert_eq!(used, bytes.len());
            assert_eq!(back, f);
        }
    }
}
