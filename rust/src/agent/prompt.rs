//! Prompt engineering (paper §4.3.2, Fig 10).
//!
//! Zero-shot ICL: a structured task definition with the system description,
//! objective, metric explanations, static graph metadata, the latest
//! observations, and the recent decision history — ending with a strict
//! JSON answer schema.  The prompt embeds the observation as a JSON block,
//! which is also what the [`super::backend::SimulatedLlm`] parses (it sees
//! only this text, exactly like a real model would).

use super::context::HistoryEntry;
use super::Observation;
use crate::util::json::Json;

/// Context-window budget (paper fixes < 2048 tokens); history is trimmed
/// to fit.  We approximate 4 chars/token.
pub const MAX_TOKENS: usize = 2048;

pub fn estimate_tokens(text: &str) -> usize {
    text.len() / 4
}

/// Observation → the JSON block embedded in the prompt.
pub fn observation_json(o: &Observation) -> Json {
    Json::obj(vec![
        ("hits_pct", Json::num(round2(o.hits_pct))),
        ("buffer_occupancy_pct", Json::num(round2(o.buffer_occupancy_pct))),
        ("stale_pct", Json::num(round2(o.stale_pct))),
        ("replaced_pct_last", Json::num(round2(o.replaced_pct_last))),
        ("comm_nodes_last", Json::num(o.comm_nodes_last as f64)),
        ("comm_nodes_ema", Json::num(round2(o.comm_nodes_ema))),
        ("minibatches_done", Json::num(o.minibatches_done as f64)),
        ("minibatches_pending", Json::num(o.minibatches_pending as f64)),
        ("epoch", Json::num(o.epoch as f64)),
        ("epochs_total", Json::num(o.epochs_total as f64)),
        ("delta_hits", Json::num(round2(o.delta_hits))),
        ("delta_comm", Json::num(round2(o.delta_comm))),
    ])
}

fn round2(x: f64) -> f64 {
    (x * 100.0).round() / 100.0
}

/// Build the full decision prompt.
pub fn build(o: &Observation, history: &[HistoryEntry]) -> String {
    let mut s = String::with_capacity(4096);
    s.push_str(
        "You are a prefetching controller embedded in a distributed GNN \
         training system (DistDGL). Each trainer keeps a fixed-size persistent \
         buffer of remote node features. A scoring policy marks rarely used \
         nodes stale; your job is to decide WHEN to run a replacement round \
         (evict stale nodes, admit recently sampled remote nodes).\n\n\
         OBJECTIVE: maximize hits_pct (fraction of sampled remote nodes served \
         from the buffer) while keeping communication (comm_nodes) low. \
         Replacements cost communication now to save communication later; \
         avoid replacements when training is nearly done \
         (minibatches_pending low) or when the buffer is already effective \
         (hits_pct high and rising).\n\n",
    );
    s.push_str("METRICS (meaning):\n\
         - hits_pct: % of sampled remote nodes found in the buffer (higher is better)\n\
         - stale_pct: % of buffer slots whose score decayed below the stale threshold\n\
         - comm_nodes_last / comm_nodes_ema: remote nodes fetched last minibatch / trend\n\
         - delta_hits / delta_comm: change since your previous decision\n\
         - replaced_pct_last: % of buffer replaced by your last replacement\n\n");
    s.push_str("GRAPH (static):\n");
    let meta = Json::obj(vec![
        ("graph_nodes", Json::num(o.graph_nodes as f64)),
        ("graph_edges", Json::num(o.graph_edges as f64)),
        ("partition_nodes", Json::num(o.partition_nodes as f64)),
        ("halo_nodes", Json::num(o.halo_nodes as f64)),
        ("buffer_capacity", Json::num(o.buffer_capacity as f64)),
    ]);
    s.push_str(&meta.to_string_pretty());
    s.push_str("\n\nCURRENT METRICS:\n");
    s.push_str(&observation_json(o).to_string_pretty());

    // History, newest first, trimmed to the token budget.
    s.push_str("\n\nRECENT DECISIONS (newest first):\n");
    let budget_chars = MAX_TOKENS * 4;
    for h in history.iter().rev() {
        let line = h.to_json().to_string_compact();
        if s.len() + line.len() + 512 > budget_chars {
            break;
        }
        s.push_str(&line);
        s.push('\n');
    }

    s.push_str(
        "\nRespond with ONLY a JSON object:\n\
         {\"action\": \"replace\" | \"skip\", \
         \"expected_hits\": \"increase\" | \"decrease\" | \"unchanged\", \
         \"reason\": \"<one sentence>\"}\n",
    );
    debug_assert!(estimate_tokens(&s) <= MAX_TOKENS + 256, "prompt over budget");
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::Action;
    use crate::metrics::HitsPrediction;

    fn obs() -> Observation {
        Observation {
            hits_pct: 42.5,
            buffer_occupancy_pct: 80.0,
            stale_pct: 12.0,
            replaced_pct_last: 5.0,
            comm_nodes_last: 1234,
            comm_nodes_ema: 1100.0,
            minibatches_done: 10,
            minibatches_pending: 90,
            epoch: 1,
            epochs_total: 5,
            delta_hits: 3.0,
            delta_comm: -50.0,
            graph_nodes: 60000,
            graph_edges: 770000,
            partition_nodes: 15000,
            halo_nodes: 9000,
            buffer_capacity: 450,
        }
    }

    fn hist(n: usize) -> Vec<HistoryEntry> {
        (0..n)
            .map(|i| HistoryEntry {
                minibatch: i as u64,
                action: if i % 2 == 0 { Action::Replace } else { Action::Skip },
                predicted: Some(HitsPrediction::Increase),
                hits_before: 30.0 + i as f64,
                hits_after: Some(31.0 + i as f64),
                comm_before: 1000.0,
                comm_after: Some(900.0),
                outcome_pass: Some(true),
            })
            .collect()
    }

    #[test]
    fn prompt_contains_all_sections() {
        let p = build(&obs(), &hist(3));
        for needle in [
            "OBJECTIVE", "GRAPH (static)", "CURRENT METRICS", "RECENT DECISIONS",
            "\"hits_pct\": 42.5", "\"action\"", "buffer_capacity",
        ] {
            assert!(p.contains(needle), "missing '{needle}'");
        }
    }

    #[test]
    fn observation_json_roundtrips() {
        let j = observation_json(&obs());
        let parsed = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(parsed.get("comm_nodes_last").unwrap().as_i64(), Some(1234));
        assert_eq!(parsed.get("delta_hits").unwrap().as_f64(), Some(3.0));
    }

    #[test]
    fn long_history_respects_token_budget() {
        let p = build(&obs(), &hist(500));
        assert!(
            estimate_tokens(&p) <= MAX_TOKENS + 256,
            "prompt {} tokens",
            estimate_tokens(&p)
        );
    }

    #[test]
    fn newest_history_survives_trimming() {
        let h = hist(500);
        let p = build(&obs(), &h);
        // The newest entry (minibatch 499) must be present.
        assert!(p.contains("\"minibatch\":499"), "newest history entry trimmed");
    }
}
