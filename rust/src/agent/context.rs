//! ContextBuilder (paper §4.2): tracks past replacement decisions and their
//! outcomes, evaluating each decision once the next metrics arrive — the
//! temporal context that lets the LLM reason about whether its last
//! intervention helped.

use super::{Action, Observation};
use crate::metrics::HitsPrediction;
use crate::util::json::Json;

/// Tolerance (percentage points) under which a %-Hits movement counts as
/// "unchanged" for outcome evaluation and Pass@1.  Sized to the sampling
/// noise of per-minibatch %-Hits at the scaled batch sizes.
pub const HITS_TOLERANCE: f64 = 2.5;

#[derive(Debug, Clone)]
pub struct HistoryEntry {
    pub minibatch: u64,
    pub action: Action,
    pub predicted: Option<HitsPrediction>,
    pub hits_before: f64,
    pub hits_after: Option<f64>,
    pub comm_before: f64,
    pub comm_after: Option<f64>,
    /// Did the observed outcome match the prediction (§4.6 pass/fail)?
    pub outcome_pass: Option<bool>,
}

impl HistoryEntry {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("minibatch", Json::num(self.minibatch as f64)),
            (
                "action",
                Json::str(match self.action {
                    Action::Replace => "replace",
                    Action::Skip => "skip",
                }),
            ),
            ("hits_before", Json::num(self.hits_before)),
        ];
        if let Some(p) = self.predicted {
            pairs.push((
                "expected_hits",
                Json::str(match p {
                    HitsPrediction::Increase => "increase",
                    HitsPrediction::Decrease => "decrease",
                    HitsPrediction::Unchanged => "unchanged",
                }),
            ));
        }
        if let Some(h) = self.hits_after {
            pairs.push(("hits_after", Json::num(h)));
            pairs.push((
                "delta_hits",
                Json::num(((h - self.hits_before) * 100.0).round() / 100.0),
            ));
        }
        if let (Some(ca), cb) = (self.comm_after, self.comm_before) {
            pairs.push(("delta_comm", Json::num(ca - cb)));
        }
        if let Some(p) = self.outcome_pass {
            pairs.push(("outcome", Json::str(if p { "pass" } else { "fail" })));
        }
        Json::obj(pairs)
    }
}

/// Maintains the decision history and closes the loop on outcomes.
#[derive(Debug, Clone, Default)]
pub struct ContextBuilder {
    history: Vec<HistoryEntry>,
    /// Maximum entries retained (prompt building trims further by tokens).
    pub max_entries: usize,
    /// How many of the newest entries are *not yet applied* when the next
    /// observation arrives (async mode: the just-polled decision acts now,
    /// so its outcome lags one poll; sync mode: 0).
    pub eval_lag: usize,
}

impl ContextBuilder {
    pub fn new() -> ContextBuilder {
        ContextBuilder { history: Vec::new(), max_entries: 32, eval_lag: 0 }
    }

    /// Record a fresh decision (pre-decision metrics captured).
    pub fn record_decision(
        &mut self,
        minibatch: u64,
        action: Action,
        predicted: Option<HitsPrediction>,
        obs: &Observation,
    ) {
        self.history.push(HistoryEntry {
            minibatch,
            action,
            predicted,
            hits_before: obs.hits_pct,
            hits_after: None,
            comm_before: obs.comm_nodes_last as f64,
            comm_after: None,
            outcome_pass: None,
        });
        if self.history.len() > self.max_entries {
            let excess = self.history.len() - self.max_entries;
            self.history.drain(..excess);
        }
    }

    /// When the next metrics arrive, evaluate the previous decision's
    /// effectiveness (step 7 in Fig 9).  Returns the pass/fail outcome if a
    /// prediction existed.
    pub fn evaluate_previous(&mut self, obs: &Observation) -> Option<bool> {
        if self.history.len() <= self.eval_lag {
            return None;
        }
        let idx = self.history.len() - 1 - self.eval_lag;
        let entry = &mut self.history[idx];
        if entry.hits_after.is_some() {
            return entry.outcome_pass;
        }
        entry.hits_after = Some(obs.hits_pct);
        entry.comm_after = Some(obs.comm_nodes_last as f64);
        let delta = obs.hits_pct - entry.hits_before;
        entry.outcome_pass = entry.predicted.map(|p| p.matches(delta, HITS_TOLERANCE));
        entry.outcome_pass
    }

    pub fn history(&self) -> &[HistoryEntry] {
        &self.history
    }

    pub fn len(&self) -> usize {
        self.history.len()
    }

    pub fn is_empty(&self) -> bool {
        self.history.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(hits: f64, comm: u64) -> Observation {
        Observation { hits_pct: hits, comm_nodes_last: comm, ..Default::default() }
    }

    #[test]
    fn records_and_evaluates() {
        let mut ctx = ContextBuilder::new();
        ctx.record_decision(5, Action::Replace, Some(HitsPrediction::Increase), &obs(40.0, 100));
        assert_eq!(ctx.len(), 1);
        assert!(ctx.history()[0].hits_after.is_none());
        // Next metrics: hits rose by 5 -> prediction passes.
        let pass = ctx.evaluate_previous(&obs(45.0, 80));
        assert_eq!(pass, Some(true));
        let e = &ctx.history()[0];
        assert_eq!(e.hits_after, Some(45.0));
        assert_eq!(e.comm_after, Some(80.0));
    }

    #[test]
    fn failed_prediction() {
        let mut ctx = ContextBuilder::new();
        ctx.record_decision(1, Action::Replace, Some(HitsPrediction::Increase), &obs(40.0, 100));
        assert_eq!(ctx.evaluate_previous(&obs(40.2, 100)), Some(false));
    }

    #[test]
    fn unchanged_prediction_uses_tolerance() {
        let mut ctx = ContextBuilder::new();
        ctx.record_decision(1, Action::Skip, Some(HitsPrediction::Unchanged), &obs(40.0, 100));
        assert_eq!(ctx.evaluate_previous(&obs(40.5, 100)), Some(true));
    }

    #[test]
    fn double_evaluate_is_idempotent() {
        let mut ctx = ContextBuilder::new();
        ctx.record_decision(1, Action::Replace, Some(HitsPrediction::Increase), &obs(40.0, 100));
        assert_eq!(ctx.evaluate_previous(&obs(50.0, 90)), Some(true));
        // Second call must not overwrite with new metrics.
        assert_eq!(ctx.evaluate_previous(&obs(0.0, 0)), Some(true));
        assert_eq!(ctx.history()[0].hits_after, Some(50.0));
    }

    #[test]
    fn bounded_history() {
        let mut ctx = ContextBuilder::new();
        ctx.max_entries = 4;
        for i in 0..10 {
            ctx.record_decision(i, Action::Skip, None, &obs(10.0, 1));
        }
        assert_eq!(ctx.len(), 4);
        assert_eq!(ctx.history()[0].minibatch, 6);
    }

    #[test]
    fn json_rendering_includes_outcome() {
        let mut ctx = ContextBuilder::new();
        ctx.record_decision(2, Action::Replace, Some(HitsPrediction::Increase), &obs(30.0, 50));
        ctx.evaluate_previous(&obs(35.0, 40));
        let j = ctx.history()[0].to_json().to_string_compact();
        assert!(j.contains("\"outcome\":\"pass\""), "{j}");
        assert!(j.contains("\"delta_hits\":5"), "{j}");
    }
}
