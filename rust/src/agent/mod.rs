//! The LLM-agent workflow (paper §4.2–4.3): MetricsCollector →
//! ContextBuilder → DecisionMaker, over a pluggable [`backend::LlmBackend`].
//!
//! The agent is *zero-shot ICL*: every decision is one structured JSON
//! prompt carrying (a) static graph/training metadata, (b) the latest
//! runtime metrics, (c) the decision history with observed outcomes.  The
//! response is parsed ([`parser`]) and validated; invalid responses are
//! tallied (Table 2's Valid/Invalid column) and treated as skip.

pub mod backend;
pub mod context;
pub mod decision;
pub mod parser;
pub mod profiles;
pub mod prompt;

use crate::metrics::HitsPrediction;

/// The agent-visible observation snapshot (paper §4.3's metric classes).
#[derive(Debug, Clone, Default)]
pub struct Observation {
    // Persistent buffer.
    pub hits_pct: f64,
    pub buffer_occupancy_pct: f64,
    pub stale_pct: f64,
    pub replaced_pct_last: f64,
    // Training progress.
    pub comm_nodes_last: u64,
    pub comm_nodes_ema: f64,
    pub minibatches_done: u64,
    pub minibatches_pending: u64,
    pub epoch: usize,
    pub epochs_total: usize,
    // Trends (vs the previous observation the agent saw).
    pub delta_hits: f64,
    pub delta_comm: f64,
    // Static graph metadata.
    pub graph_nodes: u64,
    pub graph_edges: u64,
    pub partition_nodes: u64,
    pub halo_nodes: u64,
    pub buffer_capacity: u64,
}

/// What the controller tells the prefetcher to do.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    Replace,
    Skip,
}

/// A fully processed agent step.
#[derive(Debug, Clone)]
pub struct AgentStep {
    pub action: Action,
    pub prediction: Option<HitsPrediction>,
    /// Inference latency in (virtual) seconds.
    pub latency: f64,
    pub valid_response: bool,
    /// Raw response text (kept for tracing / failure analysis).
    pub raw_response: String,
}
