//! LLM backends: the token generators behind the agent loop.
//!
//! [`LlmBackend`] is the only seam between the fully-real agent machinery
//! (prompts, parsing, queues, Pass@1) and the model:
//!
//! * [`SimulatedLlm`] — profile-driven stand-in (DESIGN.md §2): parses the
//!   *actual prompt text* (only what a real model would see), applies a
//!   profile-weighted mixture of {sound reasoning, noise, replacement
//!   bias}, and renders a JSON response — or a malformed one, at the
//!   profile's measured invalid rate.  Latency follows the profile's
//!   prefill/decode rates on the shared GPU.
//! * [`ExternalCommandBackend`] — pipes the prompt to any local command
//!   (e.g. `ollama run gemma3:4b`) for plugging a real model in; latency is
//!   measured wall-clock.

use std::io::Write;
use std::process::{Command, Stdio};

use super::prompt;
use crate::util::json::Json;
use crate::util::rng::Pcg32;

use super::profiles::LlmProfile;

#[derive(Debug, Clone)]
pub struct BackendReply {
    pub text: String,
    /// Response latency in seconds (virtual for simulated backends,
    /// wall-clock for external ones).
    pub latency: f64,
}

pub trait LlmBackend: Send {
    fn complete(&mut self, prompt_text: &str) -> BackendReply;
    fn name(&self) -> String;
}

// ---------------------------------------------------------------------------
// Simulated backend

pub struct SimulatedLlm {
    pub profile: LlmProfile,
    pub cot: bool,
    rng: Pcg32,
}

/// The metric fields the simulated model reads out of the prompt.
#[derive(Debug, Default, Clone)]
struct PromptView {
    hits_pct: f64,
    stale_pct: f64,
    occupancy_pct: f64,
    pending: f64,
    done: f64,
    delta_hits: f64,
    delta_comm: f64,
    last_outcome_pass: Option<bool>,
    last_action_replace: Option<bool>,
}

impl SimulatedLlm {
    pub fn new(profile: &LlmProfile, seed: u64, cot: bool) -> SimulatedLlm {
        SimulatedLlm { profile: profile.clone(), cot, rng: Pcg32::new(seed) }
    }

    /// Extract the CURRENT METRICS block + newest history entry from the
    /// prompt — string work only, exactly what a real model conditions on.
    fn read_prompt(text: &str) -> PromptView {
        let mut v = PromptView::default();
        if let Some(pos) = text.find("CURRENT METRICS:") {
            if let Some(j) = Json::extract_object(&text[pos..]) {
                let f = |k: &str| j.get(k).and_then(Json::as_f64).unwrap_or(0.0);
                v.hits_pct = f("hits_pct");
                v.stale_pct = f("stale_pct");
                v.occupancy_pct = f("buffer_occupancy_pct");
                v.pending = f("minibatches_pending");
                v.done = f("minibatches_done");
                v.delta_hits = f("delta_hits");
                v.delta_comm = f("delta_comm");
            }
        }
        if let Some(pos) = text.find("RECENT DECISIONS") {
            if let Some(j) = Json::extract_object(&text[pos..]) {
                v.last_outcome_pass =
                    j.get("outcome").and_then(Json::as_str).map(|s| s == "pass");
                v.last_action_replace =
                    j.get("action").and_then(Json::as_str).map(|s| s == "replace");
            }
        }
        v
    }

    /// The sound decision policy (what a strong reasoner concludes from the
    /// prompt).  Returns (replace?, expected_hits, reason).
    fn sound_policy(v: &PromptView) -> (bool, &'static str, &'static str) {
        let total = v.done + v.pending;
        let progress_left = if total > 0.0 { v.pending / total } else { 1.0 };
        // Progress awareness: no replacements near completion.
        if progress_left < 0.05 {
            return (false, "unchanged", "training nearly complete, avoid churn");
        }
        // Cold buffer: admit missed nodes aggressively — hits will rise.
        if v.occupancy_pct < 99.0 && v.hits_pct < 35.0 {
            return (true, "increase", "buffer cold; admit missed nodes");
        }
        // Last replacement did not move hits: back off (diminishing
        // returns — the trajectory behaviour of Fig 20).
        if v.last_action_replace == Some(true) && v.delta_hits <= 1.0 {
            return (false, "unchanged", "last replacement showed no hits gain");
        }
        // Healthy buffer: leave it alone.
        if v.hits_pct >= 85.0 {
            return (false, "unchanged", "hit rate already high");
        }
        // Degrading state with stale inventory to evict: refresh.
        if v.hits_pct < 70.0 && v.stale_pct > 2.0 && v.delta_hits < -1.0 {
            return (true, "increase", "hits falling and stale slots available");
        }
        // Rising communication trend with churnable inventory: refresh.
        if v.delta_comm > 0.0 && v.stale_pct > 10.0 {
            return (true, "increase", "communication rising; refresh stale slots");
        }
        (false, "unchanged", "metrics stable; hold")
    }

    /// Gemma3-1B-style pathology: reads a *rising* hit rate as decline and
    /// keeps replacing, predicting improvement every time (paper §5.3).
    fn biased_policy(v: &PromptView) -> (bool, &'static str, &'static str) {
        let _ = v;
        (true, "increase", "hit rate trend suggests decline; refresh buffer")
    }

    fn noise_policy(&mut self) -> (bool, &'static str, &'static str) {
        let replace = self.rng.chance(0.5);
        // Weak models over-predict movement (they pattern-match "my action
        // changes things"); "unchanged" is rarely volunteered.
        let r = self.rng.f64();
        let pred = if r < 0.5 {
            "increase"
        } else if r < 0.9 {
            "decrease"
        } else {
            "unchanged"
        };
        (replace, pred, "heuristic guess")
    }

    fn render_invalid(&mut self) -> String {
        match self.rng.below(4) {
            0 => "I think the buffer should probably be refreshed soon, but it \
                  depends on the communication pattern."
                .to_string(),
            1 => "{\"action\": \"replace\", \"expected_hits\": \"incre".to_string(),
            2 => "<think>The hits percentage is low so...</think> maybe replace?".to_string(),
            _ => "{\"decision\": true}".to_string(),
        }
    }
}

impl LlmBackend for SimulatedLlm {
    fn complete(&mut self, prompt_text: &str) -> BackendReply {
        let tokens = prompt::estimate_tokens(prompt_text);
        let latency = self.profile.latency(tokens, self.cot);
        // Invalid response?
        if self.rng.chance(self.profile.invalid_rate) {
            return BackendReply { text: self.render_invalid(), latency };
        }
        let view = Self::read_prompt(prompt_text);
        // CoT slightly lifts effective reasoning quality (paper §4.3.2).
        let quality =
            (self.profile.reasoning_quality + if self.cot { 0.04 } else { 0.0 }).min(1.0);
        let (replace, pred, reason) = if self.rng.chance(self.profile.replace_bias) {
            Self::biased_policy(&view)
        } else if self.rng.chance(quality) {
            Self::sound_policy(&view)
        } else {
            self.noise_policy()
        };
        let j = Json::obj(vec![
            ("action", Json::str(if replace { "replace" } else { "skip" })),
            ("expected_hits", Json::str(pred)),
            ("reason", Json::str(reason)),
        ]);
        BackendReply { text: j.to_string_compact(), latency }
    }

    fn name(&self) -> String {
        self.profile.name.to_string()
    }
}

// ---------------------------------------------------------------------------
// External command backend (real local LLMs, e.g. Ollama)

pub struct ExternalCommandBackend {
    pub command: String,
    pub args: Vec<String>,
}

impl LlmBackend for ExternalCommandBackend {
    fn complete(&mut self, prompt_text: &str) -> BackendReply {
        let start = std::time::Instant::now();
        let text = (|| -> crate::error::Result<String> {
            let mut child = Command::new(&self.command)
                .args(&self.args)
                .stdin(Stdio::piped())
                .stdout(Stdio::piped())
                .stderr(Stdio::null())
                .spawn()?;
            child
                .stdin
                .as_mut()
                .ok_or_else(|| crate::err!("no stdin"))?
                .write_all(prompt_text.as_bytes())?;
            let out = child.wait_with_output()?;
            Ok(String::from_utf8_lossy(&out.stdout).into_owned())
        })()
        .unwrap_or_default();
        BackendReply { text, latency: start.elapsed().as_secs_f64() }
    }

    fn name(&self) -> String {
        format!("external:{}", self.command)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::context::HistoryEntry;
    use crate::agent::profiles::by_name;
    use crate::agent::{Action, Observation};
    use crate::metrics::HitsPrediction;

    fn prompt_with(hits: f64, occ: f64, stale: f64, pending: f64) -> String {
        let obs = Observation {
            hits_pct: hits,
            buffer_occupancy_pct: occ,
            stale_pct: stale,
            minibatches_done: 100,
            minibatches_pending: pending as u64,
            ..Default::default()
        };
        prompt::build(&obs, &[])
    }

    #[test]
    fn strong_model_replaces_when_buffer_cold() {
        let mut llm = SimulatedLlm::new(by_name("gemma3-4b").unwrap(), 1, false);
        let reply = llm.complete(&prompt_with(5.0, 40.0, 0.0, 500.0));
        let j = Json::extract_object(&reply.text).unwrap();
        assert_eq!(j.get("action").unwrap().as_str(), Some("replace"));
        assert_eq!(j.get("expected_hits").unwrap().as_str(), Some("increase"));
    }

    #[test]
    fn strong_model_skips_when_healthy() {
        let mut llm = SimulatedLlm::new(by_name("gemma3-4b").unwrap(), 2, false);
        let reply = llm.complete(&prompt_with(92.0, 100.0, 0.5, 500.0));
        let j = Json::extract_object(&reply.text).unwrap();
        assert_eq!(j.get("action").unwrap().as_str(), Some("skip"));
    }

    #[test]
    fn strong_model_respects_progress_awareness() {
        let mut llm = SimulatedLlm::new(by_name("gemma3-4b").unwrap(), 3, false);
        // 100 done, 2 pending -> near completion.
        let reply = llm.complete(&prompt_with(30.0, 50.0, 10.0, 2.0));
        let j = Json::extract_object(&reply.text).unwrap();
        assert_eq!(j.get("action").unwrap().as_str(), Some("skip"));
    }

    #[test]
    fn gemma1b_always_replaces() {
        let mut llm = SimulatedLlm::new(by_name("gemma3-1b").unwrap(), 4, false);
        for _ in 0..20 {
            let reply = llm.complete(&prompt_with(95.0, 100.0, 0.0, 500.0));
            let j = Json::extract_object(&reply.text).unwrap();
            assert_eq!(j.get("action").unwrap().as_str(), Some("replace"));
        }
    }

    #[test]
    fn qwen_emits_invalid_responses() {
        let mut llm = SimulatedLlm::new(by_name("qwen-1.5b").unwrap(), 5, false);
        let mut invalid = 0;
        for _ in 0..200 {
            let reply = llm.complete(&prompt_with(50.0, 80.0, 5.0, 100.0));
            let parsed = crate::agent::parser::parse(&reply.text);
            if parsed.is_none() {
                invalid += 1;
            }
        }
        // invalid_rate 0.56 ± sampling noise.
        assert!((80..=140).contains(&invalid), "invalid {invalid}/200");
    }

    #[test]
    fn latency_reflects_profile() {
        let p = prompt_with(50.0, 80.0, 5.0, 100.0);
        let mut fast = SimulatedLlm::new(by_name("smollm2-360m").unwrap(), 6, false);
        let mut slow = SimulatedLlm::new(by_name("mixtral-8x22b").unwrap(), 6, false);
        assert!(fast.complete(&p).latency * 5.0 < slow.complete(&p).latency);
    }

    #[test]
    fn backs_off_after_failed_replacement() {
        // History says: replaced, hits did not move.
        let obs = Observation {
            hits_pct: 75.0,
            buffer_occupancy_pct: 100.0,
            stale_pct: 10.0,
            minibatches_done: 50,
            minibatches_pending: 200,
            delta_hits: -0.5,
            ..Default::default()
        };
        let hist = vec![HistoryEntry {
            minibatch: 49,
            action: Action::Replace,
            predicted: Some(HitsPrediction::Increase),
            hits_before: 75.5,
            hits_after: Some(75.0),
            comm_before: 100.0,
            comm_after: Some(110.0),
            outcome_pass: Some(false),
        }];
        let text = prompt::build(&obs, &hist);
        let mut llm = SimulatedLlm::new(by_name("gemma3-4b").unwrap(), 7, false);
        let reply = llm.complete(&text);
        let j = Json::extract_object(&reply.text).unwrap();
        assert_eq!(
            j.get("action").unwrap().as_str(),
            Some("skip"),
            "should back off after ineffective replacement"
        );
    }

    #[test]
    fn external_backend_runs_command() {
        let mut b = ExternalCommandBackend { command: "cat".into(), args: vec![] };
        let reply = b.complete("{\"echo\": true}");
        assert!(reply.text.contains("echo"));
        assert!(reply.latency >= 0.0);
    }
}
