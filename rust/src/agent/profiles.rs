//! LLM model profiles (paper Table 1b + Fig 6).
//!
//! Substitution (DESIGN.md §2): the sandbox cannot serve real quantized
//! LLMs, so each model the paper deployed via Ollama is represented by a
//! profile — serving characteristics (prefill/decode rates derived from
//! model size and quantization on an A100-class device), benchmark scores
//! (MATH-500 / IFEVAL, Fig 6), and *behavioural* parameters (reasoning
//! quality, JSON-compliance, replacement bias) calibrated against the
//! paper's measured failure modes (Table 2: Gemma3-1B's always-replace
//! bias, Qwen's 44% valid-response rate, SmolLM noise, MoE latency).
//! The agent loop, prompts, parsing and evaluation are fully real; only the
//! token generator is simulated.

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LlmKind {
    Base,
    Slm,
    Distill,
    Moe,
}

#[derive(Debug, Clone)]
pub struct LlmProfile {
    pub name: &'static str,
    pub kind: LlmKind,
    /// Model / KV-cache size (GB), Table 1b.
    pub size_gb: f64,
    pub kv_gb: f64,
    pub quant: &'static str,
    /// Serving rates (tokens/s) on the shared A100.
    pub prefill_tps: f64,
    pub decode_tps: f64,
    /// Mean response length (tokens) without CoT.
    pub out_tokens: f64,
    /// Benchmark scores (0–100) for the Fig 6 spider chart.
    pub math500: f64,
    pub ifeval: f64,
    /// Probability a decision follows the sound reasoning policy (vs noise).
    pub reasoning_quality: f64,
    /// Probability of emitting a malformed / non-compliant response.
    pub invalid_rate: f64,
    /// Probability of forcing "replace" regardless of reasoning (the
    /// paper's "replacement bias", §5.3).
    pub replace_bias: f64,
}

/// All models evaluated in the paper (Tables 1b, 2, 5).
pub const ALL: &[LlmProfile] = &[
    LlmProfile {
        name: "gemma3-4b", kind: LlmKind::Base,
        size_gb: 3.3, kv_gb: 0.27, quant: "Q4_K_M",
        prefill_tps: 3000.0, decode_tps: 110.0, out_tokens: 58.0,
        math500: 76.0, ifeval: 90.0,
        reasoning_quality: 0.97, invalid_rate: 0.0, replace_bias: 0.0,
    },
    LlmProfile {
        name: "gemma3-1b", kind: LlmKind::Base,
        size_gb: 0.8, kv_gb: 0.05, quant: "Q4_K_M",
        prefill_tps: 5200.0, decode_tps: 90.0, out_tokens: 46.0,
        math500: 45.0, ifeval: 80.0,
        // High compliance, but pathological policy: infers decline from
        // rising %-Hits and replaces aggressively (paper §5.3).
        reasoning_quality: 0.85, invalid_rate: 0.0, replace_bias: 1.0,
    },
    LlmProfile {
        name: "llama3.2-3b", kind: LlmKind::Base,
        size_gb: 2.0, kv_gb: 0.22, quant: "Q4_K_M",
        prefill_tps: 6000.0, decode_tps: 120.0, out_tokens: 42.0,
        math500: 51.0, ifeval: 77.0,
        reasoning_quality: 0.80, invalid_rate: 0.01, replace_bias: 0.0,
    },
    LlmProfile {
        name: "smollm2-360m", kind: LlmKind::Slm,
        size_gb: 0.38, kv_gb: 0.08, quant: "Q4_K_M",
        prefill_tps: 12000.0, decode_tps: 140.0, out_tokens: 38.0,
        math500: 19.0, ifeval: 41.0,
        reasoning_quality: 0.12, invalid_rate: 0.13, replace_bias: 0.0,
    },
    LlmProfile {
        name: "smollm2-1.7b", kind: LlmKind::Slm,
        size_gb: 1.06, kv_gb: 0.38, quant: "Q4_K_M",
        prefill_tps: 8000.0, decode_tps: 140.0, out_tokens: 44.0,
        math500: 31.0, ifeval: 56.0,
        reasoning_quality: 0.28, invalid_rate: 0.08, replace_bias: 0.45,
    },
    LlmProfile {
        name: "qwen-1.5b", kind: LlmKind::Distill,
        // DeepSeek-R1-Distill-Qwen-1.5B at F16: 10 GB, reasoning-style long
        // outputs, poor format compliance (44% valid, Table 2).
        size_gb: 10.0, kv_gb: 0.05, quant: "F16",
        prefill_tps: 1500.0, decode_tps: 150.0, out_tokens: 240.0,
        math500: 83.0, ifeval: 35.0,
        reasoning_quality: 0.55, invalid_rate: 0.56, replace_bias: 0.30,
    },
    LlmProfile {
        name: "mixtral-8x7b", kind: LlmKind::Moe,
        size_gb: 24.0, kv_gb: 0.26, quant: "Q3_K_L",
        prefill_tps: 1600.0, decode_tps: 50.0, out_tokens: 60.0,
        math500: 42.0, ifeval: 62.0,
        reasoning_quality: 0.58, invalid_rate: 0.06, replace_bias: 0.18,
    },
    LlmProfile {
        name: "mixtral-8x22b", kind: LlmKind::Moe,
        // Q2_K low-bit quantization degrades reasoning in large models
        // (paper §5.6) — quality below its size class, massive latency.
        size_gb: 52.0, kv_gb: 0.45, quant: "Q2_K",
        prefill_tps: 700.0, decode_tps: 35.0, out_tokens: 70.0,
        math500: 38.0, ifeval: 70.0,
        reasoning_quality: 0.62, invalid_rate: 0.0, replace_bias: 0.55,
    },
    LlmProfile {
        name: "granite3.1-3b", kind: LlmKind::Moe,
        size_gb: 6.6, kv_gb: 0.13, quant: "F16",
        prefill_tps: 1800.0, decode_tps: 45.0, out_tokens: 55.0,
        math500: 40.0, ifeval: 66.0,
        reasoning_quality: 0.52, invalid_rate: 0.01, replace_bias: 0.25,
    },
];

pub fn by_name(name: &str) -> Option<&'static LlmProfile> {
    ALL.iter().find(|p| p.name == name)
}

pub fn names() -> String {
    ALL.iter().map(|p| p.name).collect::<Vec<_>>().join(", ")
}

/// The models of Table 2 (non-MoE evaluation set).
pub fn table2_models() -> Vec<&'static LlmProfile> {
    ALL.iter().filter(|p| p.kind != LlmKind::Moe).collect()
}

/// The MoE set of Table 5 / Fig 21.
pub fn moe_models() -> Vec<&'static LlmProfile> {
    ALL.iter().filter(|p| p.kind == LlmKind::Moe).collect()
}

impl LlmProfile {
    /// Response latency for a prompt of `prompt_tokens`, optionally with
    /// chain-of-thought (4–5× response length, paper §4.3.2).
    pub fn latency(&self, prompt_tokens: usize, cot: bool) -> f64 {
        let out = if cot { self.out_tokens * 4.5 } else { self.out_tokens };
        prompt_tokens as f64 / self.prefill_tps + out / self.decode_tps
    }

    /// GPU memory residency (GB) — model + KV cache (Table 1b).
    pub fn memory_gb(&self) -> f64 {
        self.size_gb + self.kv_gb
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_matches_table_1b() {
        assert_eq!(ALL.len(), 9);
        assert_eq!(by_name("gemma3-4b").unwrap().size_gb, 3.3);
        assert_eq!(by_name("mixtral-8x22b").unwrap().quant, "Q2_K");
        assert!(by_name("gpt4").is_none());
        assert_eq!(table2_models().len(), 6);
        assert_eq!(moe_models().len(), 3);
    }

    #[test]
    fn latency_ordering_matches_paper() {
        // Llama3.2-3B: "least latency"; Qwen/Mixtral-22B slowest.
        let prompt = 1500;
        let llama = by_name("llama3.2-3b").unwrap().latency(prompt, false);
        let gemma4 = by_name("gemma3-4b").unwrap().latency(prompt, false);
        let qwen = by_name("qwen-1.5b").unwrap().latency(prompt, false);
        let mixtral22 = by_name("mixtral-8x22b").unwrap().latency(prompt, false);
        assert!(llama < gemma4, "llama {llama} vs gemma4 {gemma4}");
        assert!(gemma4 < qwen, "gemma4 {gemma4} vs qwen {qwen}");
        assert!(qwen < mixtral22, "qwen {qwen} vs mixtral22 {mixtral22}");
    }

    #[test]
    fn cot_multiplies_latency() {
        let p = by_name("gemma3-4b").unwrap();
        let plain = p.latency(1500, false);
        let cot = p.latency(1500, true);
        assert!(cot / plain > 2.0 && cot / plain < 6.0, "ratio {}", cot / plain);
    }

    #[test]
    fn behavioural_params_in_range() {
        for p in ALL {
            assert!((0.0..=1.0).contains(&p.reasoning_quality), "{}", p.name);
            assert!((0.0..=1.0).contains(&p.invalid_rate), "{}", p.name);
            assert!((0.0..=1.0).contains(&p.replace_bias), "{}", p.name);
            assert!(p.memory_gb() > p.size_gb);
        }
    }

    #[test]
    fn gemma1b_has_total_replace_bias() {
        assert_eq!(by_name("gemma3-1b").unwrap().replace_bias, 1.0);
    }
}
