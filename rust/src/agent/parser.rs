//! Response validation: LLM text → structured decision, or `None`
//! (Table 2's Invalid-Response accounting).
//!
//! A response is *valid* iff it contains a JSON object whose `action` is
//! exactly `"replace"` or `"skip"`.  `expected_hits` is optional but, when
//! present, must parse into a [`HitsPrediction`] — a well-formed action
//! with a garbage prediction still counts as valid (matches the paper's
//! IFEVAL-style compliance criterion on the answer format).

use super::Action;
use crate::metrics::HitsPrediction;
use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct ParsedResponse {
    pub action: Action,
    pub prediction: Option<HitsPrediction>,
    pub reason: Option<String>,
}

/// Parse an LLM response; `None` = invalid (non-compliant) response.
pub fn parse(text: &str) -> Option<ParsedResponse> {
    let j = Json::extract_object(text)?;
    let action = match j.get("action")?.as_str()? {
        "replace" => Action::Replace,
        "skip" => Action::Skip,
        _ => return None,
    };
    let prediction = j
        .get("expected_hits")
        .and_then(Json::as_str)
        .and_then(HitsPrediction::parse);
    let reason = j.get("reason").and_then(Json::as_str).map(str::to_string);
    Some(ParsedResponse { action, prediction, reason })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_clean_response() {
        let r = parse(
            r#"{"action": "replace", "expected_hits": "increase", "reason": "low hits"}"#,
        )
        .unwrap();
        assert_eq!(r.action, Action::Replace);
        assert_eq!(r.prediction, Some(HitsPrediction::Increase));
        assert_eq!(r.reason.as_deref(), Some("low hits"));
    }

    #[test]
    fn parses_json_wrapped_in_prose() {
        let r = parse(
            "Sure, here's my analysis:\n```json\n{\"action\": \"skip\", \
             \"expected_hits\": \"unchanged\"}\n```\nLet me know!",
        )
        .unwrap();
        assert_eq!(r.action, Action::Skip);
        assert_eq!(r.prediction, Some(HitsPrediction::Unchanged));
    }

    #[test]
    fn rejects_wrong_action_enum() {
        assert!(parse(r#"{"action": "maybe"}"#).is_none());
        assert!(parse(r#"{"decision": true}"#).is_none());
    }

    #[test]
    fn rejects_truncated_json() {
        assert!(parse(r#"{"action": "replace", "expected_hits": "incre"#).is_none());
    }

    #[test]
    fn rejects_plain_prose() {
        assert!(parse("I would probably replace the buffer contents now.").is_none());
    }

    #[test]
    fn action_without_prediction_is_valid() {
        let r = parse(r#"{"action": "skip"}"#).unwrap();
        assert_eq!(r.action, Action::Skip);
        assert_eq!(r.prediction, None);
    }

    #[test]
    fn garbage_prediction_tolerated() {
        let r = parse(r#"{"action": "replace", "expected_hits": "banana"}"#).unwrap();
        assert_eq!(r.prediction, None);
    }
}
