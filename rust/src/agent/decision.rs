//! DecisionMaker (paper §4.2, step 8 in Fig 9): assembles the prompt from
//! the MetricsCollector snapshot + ContextBuilder history, invokes the
//! backend, validates the response, and records the decision.

use super::backend::LlmBackend;
use super::context::ContextBuilder;
use super::{parser, prompt, Action, AgentStep, Observation};

pub struct DecisionMaker {
    pub backend: Box<dyn LlmBackend>,
    pub context: ContextBuilder,
}

impl DecisionMaker {
    pub fn new(backend: Box<dyn LlmBackend>) -> DecisionMaker {
        DecisionMaker { backend, context: ContextBuilder::new() }
    }

    /// One full agent step: evaluate the previous decision against the new
    /// observation, build the prompt, query the model, parse and record.
    pub fn decide(&mut self, minibatch: u64, obs: &Observation) -> AgentStep {
        self.context.evaluate_previous(obs);
        let prompt_text = prompt::build(obs, self.context.history());
        let reply = self.backend.complete(&prompt_text);
        let parsed = parser::parse(&reply.text);
        let (action, prediction, valid) = match parsed {
            Some(p) => (p.action, p.prediction, true),
            // Invalid response ⇒ no action (skip), no prediction.
            None => (Action::Skip, None, false),
        };
        self.context.record_decision(minibatch, action, prediction, obs);
        AgentStep {
            action,
            prediction,
            latency: reply.latency,
            valid_response: valid,
            raw_response: reply.text,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::backend::SimulatedLlm;
    use crate::agent::profiles::by_name;

    fn obs(hits: f64, occ: f64, pending: u64) -> Observation {
        Observation {
            hits_pct: hits,
            buffer_occupancy_pct: occ,
            stale_pct: 5.0,
            minibatches_done: 10,
            minibatches_pending: pending,
            ..Default::default()
        }
    }

    #[test]
    fn full_loop_records_history_and_outcomes() {
        let backend = SimulatedLlm::new(by_name("gemma3-4b").unwrap(), 1, false);
        let mut dm = DecisionMaker::new(Box::new(backend));
        let s1 = dm.decide(0, &obs(0.0, 10.0, 100));
        assert!(s1.valid_response);
        assert_eq!(s1.action, Action::Replace); // cold buffer
        assert_eq!(dm.context.len(), 1);
        // Second decision evaluates the first.
        let _s2 = dm.decide(5, &obs(40.0, 60.0, 95));
        assert_eq!(dm.context.len(), 2);
        let first = &dm.context.history()[0];
        assert_eq!(first.hits_after, Some(40.0));
        assert_eq!(first.outcome_pass, Some(true), "hits rose as predicted");
    }

    #[test]
    fn invalid_response_becomes_skip() {
        struct Garbage;
        impl LlmBackend for Garbage {
            fn complete(&mut self, _p: &str) -> super::super::backend::BackendReply {
                super::super::backend::BackendReply {
                    text: "no json at all".into(),
                    latency: 0.5,
                }
            }
            fn name(&self) -> String {
                "garbage".into()
            }
        }
        let mut dm = DecisionMaker::new(Box::new(Garbage));
        let s = dm.decide(0, &obs(50.0, 80.0, 50));
        assert!(!s.valid_response);
        assert_eq!(s.action, Action::Skip);
        assert_eq!(s.prediction, None);
    }

    #[test]
    fn latency_propagates() {
        let backend = SimulatedLlm::new(by_name("mixtral-8x22b").unwrap(), 2, false);
        let mut dm = DecisionMaker::new(Box::new(backend));
        let s = dm.decide(0, &obs(50.0, 80.0, 50));
        assert!(s.latency > 1.0, "22B model must be slow: {}", s.latency);
    }
}
