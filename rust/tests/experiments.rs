//! Experiment-harness smoke tests: every figure/table generator runs at a
//! micro scale and produces non-degenerate tables.  (The benches produce
//! the full Quick-quality outputs; these tests guard against harness rot.)

use rudder::eval::harness;
use rudder::eval::report;
use rudder::eval::Quality;

/// Micro run of an experiment id; asserts well-formed tables.
fn check(id: &str) {
    let tables = harness::run_experiment_id(id, Quality::Quick)
        .unwrap_or_else(|e| panic!("{id}: {e}"));
    assert!(!tables.is_empty(), "{id}: no tables");
    for t in &tables {
        assert!(!t.headers.is_empty(), "{id}: no headers");
        assert!(!t.rows.is_empty(), "{id}: no rows in '{}'", t.title);
        // Render + CSV must not panic and must mention every header.
        let rendered = t.render();
        for h in &t.headers {
            assert!(rendered.contains(h.as_str()), "{id}: header '{h}' missing");
        }
        let _ = t.to_csv();
    }
}

// The cheap experiments run as individual tests; the heavyweight sweeps
// (fig12/13/16/18, table2/4 — minutes each at Quick quality) are exercised
// by `cargo bench` instead.

#[test]
fn fig01_unique_remote() {
    check("fig01");
}

#[test]
fn fig03_replacement_strategies() {
    check("fig03");
}

#[test]
fn fig06_llm_characteristics() {
    check("fig06");
}

#[test]
fn fig14_buffer_comm() {
    check("fig14");
}

#[test]
fn fig15_massivegnn() {
    check("fig15");
}

#[test]
fn fig17_sync_async() {
    check("fig17");
}

#[test]
fn fig20_trajectories() {
    check("fig20");
}

#[test]
fn wire_stats_surface_in_eval_report() {
    // The cluster runtime's wire counters must flow through the same
    // report layer as every paper table: run a micro cluster, feed its
    // WireStats into eval::report, and check the numbers land.
    use rudder::cluster::{run_cluster_on, ClusterConfig};
    use rudder::sim::{build_cluster, ControllerSpec, RunConfig};
    use std::sync::Arc;
    let cfg = RunConfig {
        dataset: "ogbn-arxiv".into(),
        scale: 0.1,
        seed: 11,
        num_trainers: 2,
        batch_size: 32,
        fanout1: 5,
        fanout2: 5,
        buffer_pct: 0.25,
        epochs: 1,
        controller: ControllerSpec::Fixed,
        ..Default::default()
    };
    let (ds, part) = build_cluster(&cfg).unwrap();
    let r = run_cluster_on(Arc::new(ds), Arc::new(part), &ClusterConfig::new(cfg), None)
        .unwrap();
    let wire = report::wire_table(&r.wire);
    assert_eq!(wire.rows.len(), r.wire.len() + 1, "one row per trainer plus the total");
    let rendered = wire.render();
    for h in &wire.headers {
        assert!(rendered.contains(h.as_str()), "header '{h}' missing");
    }
    let total = r.wire_total();
    assert!(total.req_frames > 0, "micro cluster must produce wire traffic");
    let total_row = wire.rows.last().unwrap();
    assert_eq!(total_row[0], "total");
    assert_eq!(total_row[1], total.req_frames.to_string());
    assert_eq!(total_row[3], total.resp_frames.to_string());
    let _ = wire.to_csv();
    // Per-link table: every trainer contributes its server links + hub.
    let links = report::link_table(&r.wire);
    let expected: usize = r.wire.iter().map(|w| w.links.len()).sum();
    assert_eq!(links.rows.len(), expected);
    assert!(expected > 0, "links must be recorded");
    assert!(links.render().contains("hub"));
}

#[test]
fn fig03_adaptive_wins_on_hits() {
    // The core §2.1 claim at micro scale: adaptive replacement's steady
    // %-Hits beats single/infrequent replacement.
    let tables = harness::run_experiment_id("fig03", Quality::Quick).unwrap();
    let t = &tables[0];
    let hits = |name: &str| -> f64 {
        t.rows
            .iter()
            .find(|r| r[0].contains(name))
            .map(|r| r[2].trim_end_matches('%').parse::<f64>().unwrap())
            .unwrap()
    };
    let adaptive = hits("adaptive");
    let single = hits("single");
    assert!(
        adaptive > single,
        "adaptive {adaptive} must beat single-replacement {single}"
    );
}
