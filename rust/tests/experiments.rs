//! Experiment-harness smoke tests: every figure/table generator runs at a
//! micro scale and produces non-degenerate tables.  (The benches produce
//! the full Quick-quality outputs; these tests guard against harness rot.)

use rudder::eval::harness;
use rudder::eval::Quality;

/// Micro run of an experiment id; asserts well-formed tables.
fn check(id: &str) {
    let tables = harness::run_experiment_id(id, Quality::Quick)
        .unwrap_or_else(|e| panic!("{id}: {e}"));
    assert!(!tables.is_empty(), "{id}: no tables");
    for t in &tables {
        assert!(!t.headers.is_empty(), "{id}: no headers");
        assert!(!t.rows.is_empty(), "{id}: no rows in '{}'", t.title);
        // Render + CSV must not panic and must mention every header.
        let rendered = t.render();
        for h in &t.headers {
            assert!(rendered.contains(h.as_str()), "{id}: header '{h}' missing");
        }
        let _ = t.to_csv();
    }
}

// The cheap experiments run as individual tests; the heavyweight sweeps
// (fig12/13/16/18, table2/4 — minutes each at Quick quality) are exercised
// by `cargo bench` instead.

#[test]
fn fig01_unique_remote() {
    check("fig01");
}

#[test]
fn fig03_replacement_strategies() {
    check("fig03");
}

#[test]
fn fig06_llm_characteristics() {
    check("fig06");
}

#[test]
fn fig14_buffer_comm() {
    check("fig14");
}

#[test]
fn fig15_massivegnn() {
    check("fig15");
}

#[test]
fn fig17_sync_async() {
    check("fig17");
}

#[test]
fn fig20_trajectories() {
    check("fig20");
}

#[test]
fn fig03_adaptive_wins_on_hits() {
    // The core §2.1 claim at micro scale: adaptive replacement's steady
    // %-Hits beats single/infrequent replacement.
    let tables = harness::run_experiment_id("fig03", Quality::Quick).unwrap();
    let t = &tables[0];
    let hits = |name: &str| -> f64 {
        t.rows
            .iter()
            .find(|r| r[0].contains(name))
            .map(|r| r[2].trim_end_matches('%').parse::<f64>().unwrap())
            .unwrap()
    };
    let adaptive = hits("adaptive");
    let single = hits("single");
    assert!(
        adaptive > single,
        "adaptive {adaptive} must beat single-replacement {single}"
    );
}
