//! Round-trip and adversarial tests for the vendored parsers
//! (`util::json`, `util::tomlite`) — these carry all config and
//! artifact-manifest loading in the zero-dependency build, so they get
//! their own integration suite beyond the in-module unit tests.

use rudder::util::json::Json;
use rudder::util::tomlite;

// ---------------------------------------------------------------------------
// JSON

#[test]
fn json_float_int_edge_cases() {
    for (src, want) in [
        ("0", 0.0),
        ("-0", 0.0),
        ("1e3", 1000.0),
        ("1E3", 1000.0),
        ("2.5e-2", 0.025),
        ("-12.75", -12.75),
        ("1e+2", 100.0),
        ("900719925474099", 900719925474099.0),
    ] {
        let v = Json::parse(src).unwrap_or_else(|e| panic!("{src}: {e}"));
        assert_eq!(v.as_f64(), Some(want), "{src}");
        // Round-trip through the writer.
        let back = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(back.as_f64(), Some(want), "{src} round-trip");
    }
    // Integer-valued floats render without a fraction; true floats keep it.
    assert_eq!(Json::num(5.0).to_string_compact(), "5");
    assert_eq!(Json::num(5.25).to_string_compact(), "5.25");
    assert_eq!(Json::parse("42").unwrap().as_i64(), Some(42));
    assert_eq!(Json::parse("42").unwrap().as_usize(), Some(42));
    assert_eq!(Json::parse("-1").unwrap().as_usize(), None);
}

#[test]
fn json_deep_nesting_roundtrip() {
    let src = r#"{"a":{"b":{"c":{"d":[[1,2],[3,[4,{"e":"f"}]]]}}},"g":[{},[],""]}"#;
    let v = Json::parse(src).unwrap();
    for rendered in [v.to_string_compact(), v.to_string_pretty()] {
        assert_eq!(Json::parse(&rendered).unwrap(), v);
    }
    assert!(v.at("a.b.c").and_then(|c| c.get("d")).is_some());
}

#[test]
fn json_malformed_inputs_rejected_not_panicking() {
    for src in [
        "", "{", "}", "[", "]", "nul", "truth", "+1", ".5", "1e", "--1",
        "\"unterminated", "\"bad\\escape\"q", "{\"k\"}", "{\"k\":}", "{\"k\":1,}",
        "[1,]", "[1 2]", "{\"a\":1 \"b\":2}", "{1:2}", "\u{0}",
    ] {
        assert!(Json::parse(src).is_err(), "should reject: {src:?}");
    }
    // Trailing garbage after a valid value.
    assert!(Json::parse("{} {}").is_err());
    assert!(Json::parse("1 1").is_err());
}

#[test]
fn json_string_escape_roundtrip() {
    let ugly = "quote=\" backslash=\\ newline=\n tab=\t ctrl=\u{1} unicode=héllo☃";
    let v = Json::Str(ugly.to_string());
    let back = Json::parse(&v.to_string_compact()).unwrap();
    assert_eq!(back.as_str(), Some(ugly));
    // \uXXXX escapes decode.
    assert_eq!(Json::parse(r#""Aé""#).unwrap().as_str(), Some("Aé"));
}

// ---------------------------------------------------------------------------
// TOML subset

#[test]
fn toml_nested_tables_and_arrays() {
    let src = r#"
top = 1
names = ["a", "b,c", "d"]   # comma inside string
nums = [1, -2.5, 1e2]
flags = [true, false]
empty = []
[outer]
x = "y"
[outer.inner]
z = 3
[outer.inner.deepest]
w = "end"   # three levels
"#;
    let v = tomlite::parse(src).unwrap();
    assert_eq!(v.get("top").unwrap().as_i64(), Some(1));
    let names = v.get("names").unwrap().as_arr().unwrap();
    assert_eq!(names[1].as_str(), Some("b,c"));
    let nums = v.get("nums").unwrap().as_arr().unwrap();
    assert_eq!(nums[1].as_f64(), Some(-2.5));
    assert_eq!(nums[2].as_f64(), Some(100.0));
    assert_eq!(v.get("empty").unwrap().as_arr().unwrap().len(), 0);
    assert_eq!(v.at("outer.inner.z").unwrap().as_i64(), Some(3));
    assert_eq!(v.at("outer.inner.deepest.w").unwrap().as_str(), Some("end"));
}

#[test]
fn toml_float_int_edge_cases() {
    let v = tomlite::parse("a = 0\nb = -0.0\nc = 3.14159\nd = 1e-3\ne = 1E6").unwrap();
    assert_eq!(v.get("a").unwrap().as_i64(), Some(0));
    assert_eq!(v.get("c").unwrap().as_f64(), Some(3.14159));
    assert_eq!(v.get("d").unwrap().as_f64(), Some(0.001));
    assert_eq!(v.get("e").unwrap().as_f64(), Some(1_000_000.0));
}

#[test]
fn toml_malformed_inputs_rejected() {
    for src in [
        "[unterminated",
        "[]",
        "[ ]",
        "justakey",
        "k = ",
        "k = [1, 2",
        "k = \"unterminated",
        "k = maybe",
        "= 1",
        "k = 1\nk = 2",
        "[a]\nx = 1\n[a.x]\ny = 2", // x is a value, not a section
    ] {
        assert!(tomlite::parse(src).is_err(), "should reject: {src:?}");
    }
}

#[test]
fn toml_duplicate_keys_scoped_per_section() {
    // The same key in *different* sections is fine.
    let v = tomlite::parse("[a]\nk = 1\n[b]\nk = 2").unwrap();
    assert_eq!(v.at("a.k").unwrap().as_i64(), Some(1));
    assert_eq!(v.at("b.k").unwrap().as_i64(), Some(2));
}

#[test]
fn toml_roundtrips_through_json_writer() {
    // tomlite parses into Json, so config docs can be re-serialized and
    // re-parsed as JSON losslessly (how traces/calibration get persisted).
    let src = "name = \"fig12\"\n[net]\nalpha = 0.002\nbeta = 1.5e-8";
    let v = tomlite::parse(src).unwrap();
    let back = Json::parse(&v.to_string_pretty()).unwrap();
    assert_eq!(back, v);
    assert_eq!(back.at("net.beta").unwrap().as_f64(), Some(1.5e-8));
}

#[test]
fn toml_file_api_errors_helpfully() {
    let err = tomlite::parse_file(std::path::Path::new("/nonexistent-rudder.toml")).unwrap_err();
    assert!(err.to_string().contains("reading"), "{err}");
}
