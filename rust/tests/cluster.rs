//! Integration tests for the cluster runtime: determinism (same seed ⇒
//! byte-identical traffic counters across invocations), traffic parity
//! against the virtual-time sim (same config + seed ⇒ identical
//! fetched-node / buffer-hit / payload-byte counters), cross-transport
//! parity (channel vs loopback TCP vs the multiplexed event loop,
//! frame-for-frame), deterministic fault injection, and a multi-process
//! smoke through the real binary.

use std::sync::Arc;

use rudder::cluster::{
    parity_check, run_cluster_on, wire_parity, ClusterConfig, ClusterResult, ComputeMode,
    FaultSpec, Transport,
};
use rudder::graph::Dataset;
use rudder::partition::Partition;
use rudder::sim::{build_cluster, run_on, ControllerSpec, RunConfig};

/// Small 2-trainer config on the RMAT stand-in graph (0 time-scale: no
/// emulation sleeps, as fast as the machine allows).
fn quick(controller: &str) -> RunConfig {
    RunConfig {
        dataset: "ogbn-arxiv".into(),
        scale: 0.1,
        seed: 7,
        num_trainers: 2,
        batch_size: 32,
        fanout1: 5,
        fanout2: 5,
        buffer_pct: 0.25,
        epochs: 2,
        controller: ControllerSpec::parse(controller).unwrap(),
        ..Default::default()
    }
}

fn run_both(cfg: &RunConfig) -> (rudder::sim::ExperimentResult, ClusterResult) {
    let (ds, part) = build_cluster(cfg).unwrap();
    let ds = Arc::new(ds);
    let part = Arc::new(part);
    let sim_r = run_on(ds.as_ref(), part.as_ref(), cfg, None);
    let ccfg = ClusterConfig::new(cfg.clone());
    let cluster_r = run_cluster_on(ds, part, &ccfg, None).unwrap();
    (sim_r, cluster_r)
}

/// Run one cluster on a shared graph with an explicit transport + faults.
fn run_with(
    cfg: &RunConfig,
    ds: &Arc<Dataset>,
    part: &Arc<Partition>,
    transport: Transport,
    fault: Option<FaultSpec>,
) -> ClusterResult {
    let mut ccfg = ClusterConfig::new(cfg.clone());
    ccfg.transport = transport;
    ccfg.fault = fault;
    run_cluster_on(ds.clone(), part.clone(), &ccfg, None).unwrap()
}

/// Assert two runs produced bit-identical per-minibatch records.
fn assert_minibatches_identical(a: &ClusterResult, b: &ClusterResult) {
    assert_eq!(a.experiment.per_trainer.len(), b.experiment.per_trainer.len());
    for (ma, mb) in a.experiment.per_trainer.iter().zip(&b.experiment.per_trainer) {
        assert_eq!(ma.minibatches.len(), mb.minibatches.len());
        for (ra, rb) in ma.minibatches.iter().zip(&mb.minibatches) {
            assert_eq!(
                (ra.epoch, ra.minibatch, ra.hits, ra.comm_nodes, ra.comm_bytes, ra.replaced),
                (rb.epoch, rb.minibatch, rb.hits, rb.comm_nodes, rb.comm_bytes, rb.replaced)
            );
            assert_eq!(ra.step_time.to_bits(), rb.step_time.to_bits());
        }
        assert_eq!(ma.decisions.len(), mb.decisions.len());
        for (da, db) in ma.decisions.iter().zip(&mb.decisions) {
            assert_eq!((da.minibatch, da.replace), (db.minibatch, db.replace));
            assert_eq!(da.latency.to_bits(), db.latency.to_bits());
        }
    }
}

#[test]
fn parity_fixed_controller() {
    let (sim_r, cluster_r) = run_both(&quick("fixed"));
    parity_check(&sim_r, &cluster_r.experiment).unwrap();
    assert!(cluster_r.experiment.total_comm_nodes > 0);
    assert!(cluster_r.experiment.mean_hits_pct > 0.0);
}

#[test]
fn parity_no_prefetch_baseline() {
    let (sim_r, cluster_r) = run_both(&quick("none"));
    parity_check(&sim_r, &cluster_r.experiment).unwrap();
    assert_eq!(cluster_r.experiment.mean_hits_pct, 0.0);
}

#[test]
fn parity_llm_agent_async() {
    // The async LLM agent is the hard case: its decision cadence depends
    // on the virtual clock, which the cluster reproduces exactly through
    // the allreduce hub's max-vclock barrier.
    let (sim_r, cluster_r) = run_both(&quick("llm:gemma3-4b"));
    parity_check(&sim_r, &cluster_r.experiment).unwrap();
    // The decision *sequences* must replay identically, not just counts.
    for (a, b) in sim_r.per_trainer.iter().zip(&cluster_r.experiment.per_trainer) {
        assert_eq!(a.decisions.len(), b.decisions.len());
        for (da, db) in a.decisions.iter().zip(&b.decisions) {
            assert_eq!((da.minibatch, da.replace), (db.minibatch, db.replace));
            assert_eq!(da.latency, db.latency);
        }
    }
    let decisions: usize =
        cluster_r.experiment.per_trainer.iter().map(|m| m.decisions.len()).sum();
    assert!(decisions > 0, "agent must make decisions in the cluster too");
}

#[test]
fn parity_massivegnn_prepopulated() {
    let (sim_r, cluster_r) = run_both(&quick("massivegnn:8"));
    parity_check(&sim_r, &cluster_r.experiment).unwrap();
    // Warm-started buffer: first minibatch already hits, which means the
    // cluster streamed the prepopulated features successfully.
    let first = &cluster_r.experiment.per_trainer[0].minibatches[0];
    assert!(first.hits > 0, "prepopulated features must serve hits");
}

#[test]
fn deterministic_across_invocations() {
    let cfg = quick("llm:qwen-1.5b");
    let (ds, part) = build_cluster(&cfg).unwrap();
    let ds = Arc::new(ds);
    let part = Arc::new(part);
    let ccfg = ClusterConfig::new(cfg.clone());
    let a = run_cluster_on(ds.clone(), part.clone(), &ccfg, None).unwrap();
    let b = run_cluster_on(ds, part, &ccfg, None).unwrap();
    // Byte-identical traffic counters, run to run.
    parity_check(&a.experiment, &b.experiment).unwrap();
    for (ma, mb) in a.experiment.per_trainer.iter().zip(&b.experiment.per_trainer) {
        for (ra, rb) in ma.minibatches.iter().zip(&mb.minibatches) {
            assert_eq!(ra.comm_nodes, rb.comm_nodes);
            assert_eq!(ra.comm_bytes, rb.comm_bytes);
            assert_eq!(ra.hits, rb.hits);
            assert_eq!(ra.step_time.to_bits(), rb.step_time.to_bits());
        }
    }
    assert_eq!(
        a.experiment.mean_epoch_time.to_bits(),
        b.experiment.mean_epoch_time.to_bits()
    );
}

#[test]
fn wire_traffic_is_deduped_and_served() {
    let (_, cluster_r) = run_both(&quick("fixed"));
    let wire = cluster_r.wire_total();
    let logical = cluster_r.experiment.total_comm_nodes;
    assert!(wire.nodes_requested > 0);
    assert!(
        wire.nodes_requested <= logical,
        "wire {} must not exceed logical {} fetches",
        wire.nodes_requested,
        logical
    );
    assert!(wire.nodes_deduped > 0, "miss-then-admit must trigger in-flight dedup");
    assert_eq!(wire.bad_frames, 0, "protocol must be clean");
    // Every wire-requested node is served by exactly one owner server.
    let served: u64 = cluster_r.servers.iter().map(|s| s.nodes_served).sum();
    assert_eq!(served, wire.nodes_requested);
    assert!(wire.resp_bytes > wire.req_bytes, "feature payloads dominate");
    // Coalescing: with 2 partitions a trainer needs at most one request
    // frame per fetch order, so frames must be far fewer than nodes.
    assert!(wire.req_frames < wire.nodes_requested);
    // The DDP barrier ran every round (epochs × max minibatches/epoch),
    // and the longest trainer was active in every one of them.
    let longest = cluster_r
        .experiment
        .per_trainer
        .iter()
        .map(|m| m.minibatches.len() as u64)
        .max()
        .unwrap();
    assert_eq!(cluster_r.allreduce_rounds, longest);
}

#[test]
fn single_trainer_cluster_runs() {
    let mut cfg = quick("fixed");
    cfg.num_trainers = 1;
    let (sim_r, cluster_r) = run_both(&cfg);
    parity_check(&sim_r, &cluster_r.experiment).unwrap();
}

// ---------------------------------------------------------------------------
// cross-transport parity: channel vs loopback TCP (ephemeral ports)

#[test]
fn cross_transport_parity_channel_vs_tcp() {
    let cfg = quick("fixed");
    let (ds, part) = build_cluster(&cfg).unwrap();
    let ds = Arc::new(ds);
    let part = Arc::new(part);
    let sim_r = run_on(ds.as_ref(), part.as_ref(), &cfg, None);
    let chan = run_with(&cfg, &ds, &part, Transport::Channel, None);
    let tcp = run_with(&cfg, &ds, &part, Transport::Tcp, None);
    // Both transports match the sim's logical counters...
    parity_check(&sim_r, &chan.experiment).unwrap();
    parity_check(&sim_r, &tcp.experiment).unwrap();
    // ...and each other, down to per-minibatch records and exact wire
    // frame/byte counts.
    assert_minibatches_identical(&chan, &tcp);
    wire_parity(&chan.wire, &tcp.wire).unwrap();
    let wt = tcp.wire_total();
    assert!(wt.nodes_requested > 0);
    assert_eq!(wt.dup_frames, 0, "no faults injected");
    assert_eq!(wt.bad_frames, 0, "protocol must be clean over TCP");
    assert_eq!(
        wt.nodes_received, wt.nodes_requested,
        "every wire request is answered and drained"
    );
    // Every wire-requested node is served by exactly one owner server.
    let served: u64 = tcp.servers.iter().map(|s| s.nodes_served).sum();
    assert_eq!(served, wt.nodes_requested);
    // The TCP links saw real traffic in both directions.
    let first_links = &tcp.wire[0].links;
    assert_eq!(first_links.len(), cfg.num_trainers + 1, "server links + hub link");
    assert!(first_links.iter().any(|l| l.frames_sent > 0 && l.frames_recv > 0));
}

#[test]
fn cross_transport_parity_llm_agent() {
    // The async LLM agent is the decision-cadence-sensitive case; its
    // schedule must survive the socket transport bit-for-bit.
    let cfg = quick("llm:qwen-1.5b");
    let (ds, part) = build_cluster(&cfg).unwrap();
    let ds = Arc::new(ds);
    let part = Arc::new(part);
    let sim_r = run_on(ds.as_ref(), part.as_ref(), &cfg, None);
    let tcp = run_with(&cfg, &ds, &part, Transport::Tcp, None);
    parity_check(&sim_r, &tcp.experiment).unwrap();
    let chan = run_with(&cfg, &ds, &part, Transport::Channel, None);
    assert_minibatches_identical(&chan, &tcp);
    wire_parity(&chan.wire, &tcp.wire).unwrap();
}

// ---------------------------------------------------------------------------
// cross-transport parity: the event-loop backend (one readiness-polled
// thread, all of a trainer's links multiplexed over a single connection)

#[test]
fn cross_transport_parity_event_vs_channel_and_tcp() {
    let cfg = quick("fixed");
    let (ds, part) = build_cluster(&cfg).unwrap();
    let ds = Arc::new(ds);
    let part = Arc::new(part);
    let sim_r = run_on(ds.as_ref(), part.as_ref(), &cfg, None);
    let chan = run_with(&cfg, &ds, &part, Transport::Channel, None);
    let tcp = run_with(&cfg, &ds, &part, Transport::Tcp, None);
    let event = run_with(&cfg, &ds, &part, Transport::Event, None);
    // The event loop matches the sim's logical counters...
    parity_check(&sim_r, &event.experiment).unwrap();
    // ...and both sibling transports, down to per-minibatch records and
    // exact wire frame/byte counts.
    assert_minibatches_identical(&chan, &event);
    wire_parity(&chan.wire, &event.wire).unwrap();
    wire_parity(&tcp.wire, &event.wire).unwrap();
    let wt = event.wire_total();
    assert!(wt.nodes_requested > 0);
    assert_eq!(wt.bad_frames, 0, "protocol must be clean through the mux");
    assert_eq!(wt.nodes_received, wt.nodes_requested, "every request answered and drained");
    let served: u64 = event.servers.iter().map(|s| s.nodes_served).sum();
    assert_eq!(served, wt.nodes_requested);
    // All links ride one connection: per-link cells carry the mux channel
    // ids (channel p = server p, channel n = hub), and every link moved
    // real frames in both directions.
    for w in &event.wire {
        assert_eq!(w.links.len(), cfg.num_trainers + 1, "server links + hub link");
        for (i, l) in w.links.iter().enumerate() {
            assert_eq!(l.channel, i as u32, "link '{}' on wrong mux channel", l.peer);
            assert!(l.frames_sent > 0 && l.frames_recv > 0, "idle link '{}'", l.peer);
        }
    }
}

#[test]
fn cross_transport_parity_event_llm_agent() {
    // The decision-cadence-sensitive case: the async LLM agent's schedule
    // must survive frame coalescing and the mux bit-for-bit.
    let cfg = quick("llm:qwen-1.5b");
    let (ds, part) = build_cluster(&cfg).unwrap();
    let ds = Arc::new(ds);
    let part = Arc::new(part);
    let sim_r = run_on(ds.as_ref(), part.as_ref(), &cfg, None);
    let event = run_with(&cfg, &ds, &part, Transport::Event, None);
    parity_check(&sim_r, &event.experiment).unwrap();
    let chan = run_with(&cfg, &ds, &part, Transport::Channel, None);
    assert_minibatches_identical(&chan, &event);
    wire_parity(&chan.wire, &event.wire).unwrap();
}

#[test]
fn fault_injection_over_event_loop_keeps_counters_bit_identical() {
    // dup/delay faults wrap the servers' reply senders *above* the mux, so
    // duplicated and reordered responses cross the shared connection; the
    // req-id dedup must still keep every protocol counter bit-identical to
    // a clean channel run.
    let cfg = quick("massivegnn:8");
    let (ds, part) = build_cluster(&cfg).unwrap();
    let ds = Arc::new(ds);
    let part = Arc::new(part);
    let clean = run_with(&cfg, &ds, &part, Transport::Channel, None);
    let fault = FaultSpec { seed: 13, dup: 0.4, delay: 0.4, chop: 0 };
    let faulted = run_with(&cfg, &ds, &part, Transport::Event, Some(fault));
    parity_check(&clean.experiment, &faulted.experiment).unwrap();
    assert_minibatches_identical(&clean, &faulted);
    wire_parity(&clean.wire, &faulted.wire).unwrap();
    assert!(faulted.wire_total().dup_frames > 0, "dup faults must fire");
    assert_eq!(faulted.wire_total().bad_frames, 0, "dups must still parse");
}

// ---------------------------------------------------------------------------
// deterministic fault injection

#[test]
fn fault_injection_dup_delay_keeps_counters_bit_identical() {
    let cfg = quick("fixed");
    let (ds, part) = build_cluster(&cfg).unwrap();
    let ds = Arc::new(ds);
    let part = Arc::new(part);
    let clean = run_with(&cfg, &ds, &part, Transport::Channel, None);
    let fault = FaultSpec { seed: 99, dup: 0.4, delay: 0.4, chop: 0 };
    let faulted = run_with(&cfg, &ds, &part, Transport::Channel, Some(fault));
    // Decisions and every protocol counter are unchanged by duplicated and
    // reordered responses; only dup_frames records the injected copies.
    parity_check(&clean.experiment, &faulted.experiment).unwrap();
    assert_minibatches_identical(&clean, &faulted);
    wire_parity(&clean.wire, &faulted.wire).unwrap();
    assert_eq!(clean.wire_total().dup_frames, 0);
    assert!(
        faulted.wire_total().dup_frames > 0,
        "dup=0.4 over {} response frames must fire",
        faulted.wire_total().resp_frames
    );
    // Faulted runs replay exactly: same seed, same schedule, same counters.
    let replay = run_with(&cfg, &ds, &part, Transport::Channel, Some(fault));
    wire_parity(&faulted.wire, &replay.wire).unwrap();
    assert_eq!(faulted.wire_total().dup_frames, replay.wire_total().dup_frames);
}

#[test]
fn fault_injection_over_tcp_with_chopped_writes() {
    // Chop forces the reassembly path on every response; dup/delay ride
    // along.  Counters must still match a clean channel run exactly.
    let cfg = quick("massivegnn:8");
    let (ds, part) = build_cluster(&cfg).unwrap();
    let ds = Arc::new(ds);
    let part = Arc::new(part);
    let clean = run_with(&cfg, &ds, &part, Transport::Channel, None);
    // 61-byte writes never align with frame boundaries, so every response
    // crosses the reassembly path (without drowning loopback in syscalls).
    let fault = FaultSpec { seed: 7, dup: 0.3, delay: 0.3, chop: 61 };
    let faulted = run_with(&cfg, &ds, &part, Transport::Tcp, Some(fault));
    parity_check(&clean.experiment, &faulted.experiment).unwrap();
    assert_minibatches_identical(&clean, &faulted);
    wire_parity(&clean.wire, &faulted.wire).unwrap();
    assert!(faulted.wire_total().dup_frames > 0, "dup faults must fire");
    assert_eq!(faulted.wire_total().bad_frames, 0, "chopped frames must reassemble");
}

// ---------------------------------------------------------------------------
// content-addressed chunk cache: the feature plane with `chunk_cache_bytes`
// set must keep every parity guarantee while strictly reducing response
// traffic across repeated touches

/// `quick` with the chunk protocol on: 32-row chunks, a budget generous
/// enough that nothing evicts at this scale.
fn quick_cached(controller: &str) -> RunConfig {
    let mut cfg = quick(controller);
    cfg.chunk_rows = 32;
    cfg.chunk_cache_bytes = 8 * 1024 * 1024;
    cfg
}

#[test]
fn chunk_cache_cross_transport_wire_parity() {
    // Cache admission/eviction is command-time-only, so hit/miss decisions
    // — and every frame and byte on the wire — must stay bit-identical
    // across channel, tcp, and the event loop, and the *logical* traffic
    // counters must still match the virtual-time sim exactly.
    let cfg = quick_cached("fixed");
    let (ds, part) = build_cluster(&cfg).unwrap();
    let ds = Arc::new(ds);
    let part = Arc::new(part);
    let sim_r = run_on(ds.as_ref(), part.as_ref(), &cfg, None);
    let chan = run_with(&cfg, &ds, &part, Transport::Channel, None);
    let tcp = run_with(&cfg, &ds, &part, Transport::Tcp, None);
    let event = run_with(&cfg, &ds, &part, Transport::Event, None);
    parity_check(&sim_r, &chan.experiment).unwrap();
    assert_minibatches_identical(&chan, &tcp);
    assert_minibatches_identical(&chan, &event);
    wire_parity(&chan.wire, &tcp.wire).unwrap();
    wire_parity(&chan.wire, &event.wire).unwrap();
    let wt = chan.wire_total();
    assert!(wt.chunks_fetched > 0, "misses must fetch chunks");
    assert!(wt.chunks_hit > 0, "repeated touches must hit the cache");
    assert!(wt.bytes_saved_cache > 0, "hits must account saved bytes");
    assert_eq!(wt.bad_frames, 0, "chunk protocol must be clean");
}

#[test]
fn chunk_cache_reduces_wire_bytes_over_two_epochs() {
    // The point of the cache: over 2 epochs the same remote rows are
    // re-fetched many times in the row protocol, but at most once per
    // chunk with the cache on — response bytes must strictly drop.
    let uncached = quick("massivegnn:8");
    let cached = quick_cached("massivegnn:8");
    let (ds, part) = build_cluster(&uncached).unwrap();
    let ds = Arc::new(ds);
    let part = Arc::new(part);
    let base = run_with(&uncached, &ds, &part, Transport::Channel, None);
    let warm = run_with(&cached, &ds, &part, Transport::Channel, None);
    // Logical traffic is identical — only the wire layer changes.
    parity_check(&base.experiment, &warm.experiment).unwrap();
    let wb = base.wire_total();
    let ww = warm.wire_total();
    assert!(
        ww.resp_bytes < wb.resp_bytes,
        "cache must reduce response bytes ({} cached vs {} uncached)",
        ww.resp_bytes,
        wb.resp_bytes
    );
    assert_eq!(wb.chunks_hit, 0, "row protocol never touches the cache");
    assert!(ww.chunks_hit > 0 && ww.bytes_saved_cache > 0);
    assert_eq!(ww.bad_frames, 0);
}

#[test]
fn chunk_cache_eviction_under_faults_keeps_counters_bit_identical() {
    // A tight budget forces real LRU eviction traffic, and the fault shim
    // duplicates/reorders the chunked responses — the command-time cache
    // discipline must keep every counter bit-identical to a clean channel
    // run anyway.
    let mut cfg = quick_cached("massivegnn:8");
    cfg.chunk_cache_bytes = 256 * 1024;
    let (ds, part) = build_cluster(&cfg).unwrap();
    let ds = Arc::new(ds);
    let part = Arc::new(part);
    let clean = run_with(&cfg, &ds, &part, Transport::Channel, None);
    let fault = FaultSpec { seed: 21, dup: 0.4, delay: 0.4, chop: 0 };
    let faulted = run_with(&cfg, &ds, &part, Transport::Event, Some(fault));
    parity_check(&clean.experiment, &faulted.experiment).unwrap();
    assert_minibatches_identical(&clean, &faulted);
    wire_parity(&clean.wire, &faulted.wire).unwrap();
    assert!(faulted.wire_total().dup_frames > 0, "dup faults must fire");
    assert_eq!(faulted.wire_total().bad_frames, 0, "dups must still parse");
    let wt = clean.wire_total();
    assert!(wt.chunks_fetched > 0 && wt.chunks_hit > 0, "cache must be exercised");
}

// ---------------------------------------------------------------------------
// measured compute: real SageRunner fwd/bwd behind the same state machine

/// Run one cluster on a shared graph with an explicit compute mode.
fn run_compute(
    cfg: &RunConfig,
    ds: &Arc<Dataset>,
    part: &Arc<Partition>,
    compute: ComputeMode,
    transport: Transport,
) -> ClusterResult {
    let mut ccfg = ClusterConfig::new(cfg.clone());
    ccfg.compute = compute;
    ccfg.transport = transport;
    run_cluster_on(ds.clone(), part.clone(), &ccfg, None).unwrap()
}

#[test]
fn measured_mode_counters_bit_identical_to_emulated() {
    // The tentpole guarantee: swapping sleeps for real SageRunner compute
    // must not move a single decision or traffic counter — only the clock
    // source changes.  Counters must also match the virtual-time sim.
    let cfg = quick("massivegnn:8");
    let (ds, part) = build_cluster(&cfg).unwrap();
    let ds = Arc::new(ds);
    let part = Arc::new(part);
    let sim_r = run_on(ds.as_ref(), part.as_ref(), &cfg, None);
    let emulated = run_compute(&cfg, &ds, &part, ComputeMode::Emulated(0.0), Transport::Channel);
    let measured = run_compute(&cfg, &ds, &part, ComputeMode::Measured, Transport::Channel);
    parity_check(&sim_r, &measured.experiment).unwrap();
    parity_check(&emulated.experiment, &measured.experiment).unwrap();
    assert_minibatches_identical(&emulated, &measured);
    wire_parity(&emulated.wire, &measured.wire).unwrap();
    // Emulated runs carry no measured stats; measured runs must.
    assert!(emulated.measured.iter().all(|m| !m.is_populated()));
    for m in &measured.measured {
        assert!(m.is_populated());
        assert_eq!(m.compute_secs.len(), m.losses.len());
        assert!(m.total_compute() > 0.0, "real fwd/bwd must cost wall time");
        assert_eq!(m.rows_fallback, 0, "assembly barrier must cover every remote row");
        assert!(m.rows_local > 0, "partition-resident rows are gathered locally");
        assert!(m.grad_bytes > 0, "gradient blobs must cross the hub link");
    }
    // The buffer serves hits, so some sampled remote rows must have been
    // gathered from the prefetched feature store.
    let store_rows: u64 = measured.measured.iter().map(|m| m.rows_from_store).sum();
    assert!(store_rows > 0, "measured compute must consume prefetched features");
}

#[test]
fn measured_gradient_allreduce_is_deterministic_and_synced() {
    let cfg = quick("fixed");
    let (ds, part) = build_cluster(&cfg).unwrap();
    let ds = Arc::new(ds);
    let part = Arc::new(part);
    let a = run_compute(&cfg, &ds, &part, ComputeMode::Measured, Transport::Channel);
    let b = run_compute(&cfg, &ds, &part, ComputeMode::Measured, Transport::Channel);
    // Replicas end bit-identical within a run (real DDP sync)...
    let first = a.measured[0].param_hash;
    assert_ne!(first, 0, "measured mode must fingerprint the final params");
    assert!(a.measured.iter().all(|m| m.param_hash == first), "replicas diverged");
    // ...and across runs (ordered hub reduction ⇒ deterministic blobs).
    for (ma, mb) in a.measured.iter().zip(&b.measured) {
        assert_eq!(ma.param_hash, mb.param_hash, "gradient reduction must be deterministic");
        assert_eq!(ma.losses.len(), mb.losses.len());
        for (la, lb) in ma.losses.iter().zip(&mb.losses) {
            assert_eq!(la.to_bits(), lb.to_bits(), "losses must replay bit-identically");
        }
        assert_eq!(ma.rows_from_store, mb.rows_from_store);
        assert_eq!(ma.rows_local, mb.rows_local);
    }
    // Training moves the parameters away from their shared init: a
    // regression that zeroes every gradient delta would leave the
    // replicas bit-identical *at init*, which hash-equality alone cannot
    // catch — compare against the init fingerprint directly.
    let shape = rudder::gnn::SageShape {
        batch: cfg.batch_size,
        fanout1: cfg.fanout1,
        fanout2: cfg.fanout2,
        feat_dim: ds.spec.feat_dim,
        hidden: cfg.hidden,
        classes: ds.spec.num_classes,
    };
    let init = rudder::gnn::SageState::init(
        shape,
        rudder::util::rng::derive_seed(cfg.seed, &[0xDD]),
    );
    assert_ne!(first, init.fingerprint(), "real gradients must move the replicas off init");
    let losses = &a.measured[0].losses;
    assert!(!losses.is_empty() && losses.iter().all(|l| l.is_finite()));
}

#[test]
fn measured_mode_parity_over_tcp() {
    // The acceptance bar: measured compute with the TCP transport keeps
    // both sim parity and exact cross-transport wire parity.
    let cfg = quick("fixed");
    let (ds, part) = build_cluster(&cfg).unwrap();
    let ds = Arc::new(ds);
    let part = Arc::new(part);
    let sim_r = run_on(ds.as_ref(), part.as_ref(), &cfg, None);
    let chan = run_compute(&cfg, &ds, &part, ComputeMode::Measured, Transport::Channel);
    let tcp = run_compute(&cfg, &ds, &part, ComputeMode::Measured, Transport::Tcp);
    parity_check(&sim_r, &tcp.experiment).unwrap();
    assert_minibatches_identical(&chan, &tcp);
    wire_parity(&chan.wire, &tcp.wire).unwrap();
    // The real allreduce is transport-independent too.
    assert_eq!(chan.measured[0].param_hash, tcp.measured[0].param_hash);
}

#[test]
fn measured_mode_parity_over_event_loop() {
    // The acceptance bar for the event backend: real SageRunner compute
    // over the multiplexed connection keeps sim parity, exact wire parity
    // against both sibling transports, and the deterministic allreduce.
    let cfg = quick("fixed");
    let (ds, part) = build_cluster(&cfg).unwrap();
    let ds = Arc::new(ds);
    let part = Arc::new(part);
    let sim_r = run_on(ds.as_ref(), part.as_ref(), &cfg, None);
    let chan = run_compute(&cfg, &ds, &part, ComputeMode::Measured, Transport::Channel);
    let event = run_compute(&cfg, &ds, &part, ComputeMode::Measured, Transport::Event);
    parity_check(&sim_r, &event.experiment).unwrap();
    assert_minibatches_identical(&chan, &event);
    wire_parity(&chan.wire, &event.wire).unwrap();
    assert_eq!(chan.measured[0].param_hash, event.measured[0].param_hash);
    assert!(event.measured.iter().all(|m| m.is_populated()));
}

#[test]
fn measured_mode_parity_with_chunk_cache() {
    // Real compute consuming cache-served rows: the gathered features are
    // identical bytes whether they came off the wire or out of a chunk,
    // so the trained replicas must end bit-identical to a cache-off
    // measured run, and wire parity must hold across transports with the
    // cache on.
    let cfg = quick_cached("fixed");
    let mut cfg_off = cfg.clone();
    cfg_off.chunk_cache_bytes = 0;
    let (ds, part) = build_cluster(&cfg).unwrap();
    let ds = Arc::new(ds);
    let part = Arc::new(part);
    let plain = run_compute(&cfg_off, &ds, &part, ComputeMode::Measured, Transport::Channel);
    let chan = run_compute(&cfg, &ds, &part, ComputeMode::Measured, Transport::Channel);
    let event = run_compute(&cfg, &ds, &part, ComputeMode::Measured, Transport::Event);
    assert_eq!(
        plain.measured[0].param_hash, chan.measured[0].param_hash,
        "cache-served rows must train to the same parameters"
    );
    parity_check(&plain.experiment, &chan.experiment).unwrap();
    assert_minibatches_identical(&chan, &event);
    wire_parity(&chan.wire, &event.wire).unwrap();
    assert_eq!(chan.measured[0].param_hash, event.measured[0].param_hash);
    assert!(chan.wire_total().chunks_hit > 0, "measured run must hit the cache");
}

// ---------------------------------------------------------------------------
// multi-process smoke: the real binary, one OS process per role

#[test]
fn multiproc_tcp_parity_through_real_binary() {
    let exe = env!("CARGO_BIN_EXE_rudder");
    let out = std::process::Command::new(exe)
        .args([
            "cluster",
            "--dataset",
            "ogbn-arxiv",
            "--scale",
            "0.1",
            "--trainers",
            "2",
            "--epochs",
            "1",
            "--seed",
            "7",
            "--controller",
            "fixed",
            "--transport",
            "tcp",
            "--time-scale",
            "0",
            "--parity",
        ])
        .output()
        .expect("spawn rudder cluster --transport tcp");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "exit {:?}\nstdout:\n{stdout}\nstderr:\n{stderr}", out.status);
    assert!(stdout.contains("parity OK"), "missing sim parity:\n{stdout}");
    assert!(
        stdout.contains("cross-transport parity OK"),
        "missing channel-vs-tcp parity:\n{stdout}"
    );
}

#[test]
fn multiproc_tcp_measured_results_over_wire() {
    // Measured compute through the real binary: one OS process per role,
    // results returned over the orchestrator's results listener (no --out
    // files), parity against both the sim and the channel transport.
    let exe = env!("CARGO_BIN_EXE_rudder");
    let out = std::process::Command::new(exe)
        .args([
            "cluster",
            "--dataset",
            "ogbn-arxiv",
            "--scale",
            "0.1",
            "--trainers",
            "2",
            "--epochs",
            "1",
            "--seed",
            "7",
            "--controller",
            "fixed",
            "--transport",
            "tcp",
            "--compute",
            "measured",
            "--parity",
        ])
        .output()
        .expect("spawn rudder cluster --compute measured");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "exit {:?}\nstdout:\n{stdout}\nstderr:\n{stderr}", out.status);
    assert!(stdout.contains("parity OK"), "missing sim parity:\n{stdout}");
    assert!(
        stdout.contains("cross-transport parity OK"),
        "missing channel-vs-tcp parity:\n{stdout}"
    );
    assert!(
        stdout.contains("measured compute per trainer"),
        "missing measured-compute table:\n{stdout}"
    );
}

// ---------------------------------------------------------------------------
// flight recorder: completeness, wire cross-checks, cross-transport diff

/// Run one traced cluster on a shared graph.
fn run_traced(
    cfg: &RunConfig,
    ds: &Arc<Dataset>,
    part: &Arc<Partition>,
    transport: Transport,
) -> ClusterResult {
    let mut ccfg = ClusterConfig::new(cfg.clone());
    ccfg.transport = transport;
    ccfg.trace = true;
    run_cluster_on(ds.clone(), part.clone(), &ccfg, None).unwrap()
}

#[test]
fn trace_is_complete_and_consistent_with_wire_counters() {
    use rudder::trace::{EventKind, Role};
    let cfg = quick("massivegnn:8");
    let (ds, part) = build_cluster(&cfg).unwrap();
    let (ds, part) = (Arc::new(ds), Arc::new(part));
    let r = run_traced(&cfg, &ds, &part, Transport::Channel);
    let t = r.trace.as_ref().expect("trace requested but not returned");

    // The drain-path audit: gapless seqs, one terminal RoleEnd per stream,
    // RoleEnd.emitted == events collected.  Any buffer dropped between a
    // role thread and the orchestrator fails here.
    t.verify_complete().unwrap();

    // Every role that ran must have produced a stream.
    for (role, want) in [
        (Role::Trainer, cfg.num_trainers),
        (Role::Prefetcher, cfg.num_trainers),
        (Role::Server, cfg.num_trainers),
        (Role::Hub, 1),
    ] {
        let ids: std::collections::BTreeSet<u32> = t
            .events
            .iter()
            .filter(|e| e.role == role)
            .map(|e| e.id)
            .collect();
        assert_eq!(ids.len(), want, "{} streams missing: {ids:?}", role.name());
    }

    // Emitted-vs-collected cross-checks against independently kept
    // counters: the trace must agree with the wire layer event for event.
    let count = |f: &dyn Fn(&EventKind) -> bool| -> u64 {
        t.events.iter().filter(|e| f(&e.kind)).count() as u64
    };
    let wire = r.wire_total();
    assert_eq!(
        count(&|k| matches!(k, EventKind::FetchIssue { .. })),
        wire.req_frames,
        "one FetchIssue per request frame"
    );
    assert_eq!(
        count(&|k| matches!(k, EventKind::FetchResponse { .. })),
        wire.resp_frames,
        "one FetchResponse per admitted response frame (duplicates are silent)"
    );
    let begins = count(&|k| matches!(k, EventKind::MinibatchBegin { .. }));
    let ends = count(&|k| matches!(k, EventKind::MinibatchEnd { .. }));
    assert!(begins > 0, "trainers must emit minibatch events");
    assert_eq!(begins, ends, "every minibatch must close");
    assert_eq!(
        count(&|k| matches!(k, EventKind::AllreduceRound { .. })),
        r.allreduce_rounds,
        "one AllreduceRound trace event per hub round"
    );
}

#[test]
fn trace_verify_complete_detects_dropped_events() {
    let cfg = quick("fixed");
    let (ds, part) = build_cluster(&cfg).unwrap();
    let (ds, part) = (Arc::new(ds), Arc::new(part));
    let r = run_traced(&cfg, &ds, &part, Transport::Channel);
    let good = r.trace.unwrap();
    good.verify_complete().unwrap();

    // Dropping any single mid-stream event must be caught (seq gap or
    // RoleEnd emitted-count mismatch) — the regression guard for silent
    // drops at shutdown.
    let mut truncated = good.clone();
    let victim = truncated
        .events
        .iter()
        .position(|e| !matches!(e.kind, rudder::trace::EventKind::RoleEnd { .. }))
        .expect("some non-terminal event");
    truncated.events.remove(victim);
    let err = truncated.verify_complete().unwrap_err().to_string();
    assert!(err.contains("dropped"), "unexpected error: {err}");
}

#[test]
fn cross_transport_trace_diff_is_virtual_time_identical() {
    // The trace-level generalization of `wire_parity`: same config + seed
    // on the channel, in-process tcp, and event transports must agree on
    // every virtual-time field once wall clocks and arrival order are
    // projected out.
    let cfg = quick("llm:gemma3-4b");
    let (ds, part) = build_cluster(&cfg).unwrap();
    let (ds, part) = (Arc::new(ds), Arc::new(part));
    let chan = run_traced(&cfg, &ds, &part, Transport::Channel);
    let tcp = run_traced(&cfg, &ds, &part, Transport::Tcp);
    let event = run_traced(&cfg, &ds, &part, Transport::Event);
    let t_chan = chan.trace.unwrap();
    for (name, other) in [("tcp", tcp.trace.unwrap()), ("event", event.trace.unwrap())] {
        let report = rudder::trace::diff::diff(&t_chan, &other);
        assert!(
            report.identical(),
            "channel vs {name} trace diverged:\n{}",
            report.render()
        );
        assert!(report.events > 0, "diff must actually compare events");
    }
}

#[test]
fn untraced_run_returns_no_trace() {
    let cfg = quick("fixed");
    let (ds, part) = build_cluster(&cfg).unwrap();
    let ccfg = ClusterConfig::new(cfg.clone());
    let r = run_cluster_on(Arc::new(ds), Arc::new(part), &ccfg, None).unwrap();
    assert!(r.trace.is_none(), "tracing is strictly opt-in");
}

#[test]
fn multiproc_trace_ships_over_result_blobs() {
    // TCP worker processes return their trace buffers inside the ipc
    // result blobs; the orchestrator's merged trace must then be
    // virtual-time identical to an in-process channel run of the same
    // seed — through the real binary and `rudder trace diff`.
    let exe = env!("CARGO_BIN_EXE_rudder");
    let dir = std::env::temp_dir().join(format!("rudder-trace-it-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let record = |transport: &str| -> std::path::PathBuf {
        let path = dir.join(format!("{transport}.trace"));
        let out = std::process::Command::new(exe)
            .args([
                "cluster",
                "--dataset",
                "ogbn-arxiv",
                "--scale",
                "0.1",
                "--trainers",
                "2",
                "--epochs",
                "1",
                "--seed",
                "7",
                "--controller",
                "fixed",
                "--transport",
                transport,
                "--time-scale",
                "0",
                "--trace",
                path.to_str().unwrap(),
            ])
            .output()
            .expect("spawn rudder cluster --trace");
        assert!(
            out.status.success(),
            "{transport} run failed: {}\n{}",
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr)
        );
        path
    };
    let chan = record("channel");
    let tcp = record("tcp");
    let out = std::process::Command::new(exe)
        .args(["trace", "diff", chan.to_str().unwrap(), tcp.to_str().unwrap()])
        .output()
        .expect("spawn rudder trace diff");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        out.status.success(),
        "trace diff failed:\n{stdout}\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(stdout.contains("traces identical"), "unexpected diff output:\n{stdout}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Wall-clock overlap check: with emulated costs, prefetching must beat
/// the no-prefetch baseline.  Timing-based, so ignored by default (CI
/// runs it through the `cluster --compare-prefetch` smoke instead).
#[test]
#[ignore]
fn prefetch_beats_no_prefetch_wall_clock() {
    let cfg = quick("fixed");
    let (ds, part) = build_cluster(&cfg).unwrap();
    let ds = Arc::new(ds);
    let part = Arc::new(part);
    let mut on = ClusterConfig::new(cfg.clone());
    on.compute = ComputeMode::Emulated(0.02);
    let mut off = on.clone();
    off.run.controller = ControllerSpec::NoPrefetch;
    let r_on = run_cluster_on(ds.clone(), part.clone(), &on, None).unwrap();
    let r_off = run_cluster_on(ds, part, &off, None).unwrap();
    assert!(
        r_on.wall_total < r_off.wall_total,
        "prefetch on {}s vs off {}s",
        r_on.wall_total,
        r_off.wall_total
    );
}
