//! Integration tests for the in-process cluster runtime: determinism
//! (same seed ⇒ byte-identical traffic counters across invocations) and
//! traffic parity against the virtual-time sim (same config + seed ⇒
//! identical fetched-node / buffer-hit / payload-byte counters).

use std::sync::Arc;

use rudder::cluster::{parity_check, run_cluster_on, ClusterConfig, ClusterResult};
use rudder::sim::{build_cluster, run_on, ControllerSpec, RunConfig};

/// Small 2-trainer config on the RMAT stand-in graph (0 time-scale: no
/// emulation sleeps, as fast as the machine allows).
fn quick(controller: &str) -> RunConfig {
    RunConfig {
        dataset: "ogbn-arxiv".into(),
        scale: 0.1,
        seed: 7,
        num_trainers: 2,
        batch_size: 32,
        fanout1: 5,
        fanout2: 5,
        buffer_pct: 0.25,
        epochs: 2,
        controller: ControllerSpec::parse(controller).unwrap(),
        ..Default::default()
    }
}

fn run_both(cfg: &RunConfig) -> (rudder::sim::ExperimentResult, ClusterResult) {
    let (ds, part) = build_cluster(cfg).unwrap();
    let ds = Arc::new(ds);
    let part = Arc::new(part);
    let sim_r = run_on(ds.as_ref(), part.as_ref(), cfg, None);
    let ccfg = ClusterConfig::new(cfg.clone());
    let cluster_r = run_cluster_on(ds, part, &ccfg, None).unwrap();
    (sim_r, cluster_r)
}

#[test]
fn parity_fixed_controller() {
    let (sim_r, cluster_r) = run_both(&quick("fixed"));
    parity_check(&sim_r, &cluster_r.experiment).unwrap();
    assert!(cluster_r.experiment.total_comm_nodes > 0);
    assert!(cluster_r.experiment.mean_hits_pct > 0.0);
}

#[test]
fn parity_no_prefetch_baseline() {
    let (sim_r, cluster_r) = run_both(&quick("none"));
    parity_check(&sim_r, &cluster_r.experiment).unwrap();
    assert_eq!(cluster_r.experiment.mean_hits_pct, 0.0);
}

#[test]
fn parity_llm_agent_async() {
    // The async LLM agent is the hard case: its decision cadence depends
    // on the virtual clock, which the cluster reproduces exactly through
    // the allreduce hub's max-vclock barrier.
    let (sim_r, cluster_r) = run_both(&quick("llm:gemma3-4b"));
    parity_check(&sim_r, &cluster_r.experiment).unwrap();
    // The decision *sequences* must replay identically, not just counts.
    for (a, b) in sim_r.per_trainer.iter().zip(&cluster_r.experiment.per_trainer) {
        assert_eq!(a.decisions.len(), b.decisions.len());
        for (da, db) in a.decisions.iter().zip(&b.decisions) {
            assert_eq!((da.minibatch, da.replace), (db.minibatch, db.replace));
            assert_eq!(da.latency, db.latency);
        }
    }
    let decisions: usize =
        cluster_r.experiment.per_trainer.iter().map(|m| m.decisions.len()).sum();
    assert!(decisions > 0, "agent must make decisions in the cluster too");
}

#[test]
fn parity_massivegnn_prepopulated() {
    let (sim_r, cluster_r) = run_both(&quick("massivegnn:8"));
    parity_check(&sim_r, &cluster_r.experiment).unwrap();
    // Warm-started buffer: first minibatch already hits, which means the
    // cluster streamed the prepopulated features successfully.
    let first = &cluster_r.experiment.per_trainer[0].minibatches[0];
    assert!(first.hits > 0, "prepopulated features must serve hits");
}

#[test]
fn deterministic_across_invocations() {
    let cfg = quick("llm:qwen-1.5b");
    let (ds, part) = build_cluster(&cfg).unwrap();
    let ds = Arc::new(ds);
    let part = Arc::new(part);
    let ccfg = ClusterConfig::new(cfg.clone());
    let a = run_cluster_on(ds.clone(), part.clone(), &ccfg, None).unwrap();
    let b = run_cluster_on(ds, part, &ccfg, None).unwrap();
    // Byte-identical traffic counters, run to run.
    parity_check(&a.experiment, &b.experiment).unwrap();
    for (ma, mb) in a.experiment.per_trainer.iter().zip(&b.experiment.per_trainer) {
        for (ra, rb) in ma.minibatches.iter().zip(&mb.minibatches) {
            assert_eq!(ra.comm_nodes, rb.comm_nodes);
            assert_eq!(ra.comm_bytes, rb.comm_bytes);
            assert_eq!(ra.hits, rb.hits);
            assert_eq!(ra.step_time.to_bits(), rb.step_time.to_bits());
        }
    }
    assert_eq!(
        a.experiment.mean_epoch_time.to_bits(),
        b.experiment.mean_epoch_time.to_bits()
    );
}

#[test]
fn wire_traffic_is_deduped_and_served() {
    let (_, cluster_r) = run_both(&quick("fixed"));
    let wire = cluster_r.wire_total();
    let logical = cluster_r.experiment.total_comm_nodes;
    assert!(wire.nodes_requested > 0);
    assert!(
        wire.nodes_requested <= logical,
        "wire {} must not exceed logical {} fetches",
        wire.nodes_requested,
        logical
    );
    assert!(wire.nodes_deduped > 0, "miss-then-admit must trigger in-flight dedup");
    assert_eq!(wire.bad_frames, 0, "protocol must be clean");
    // Every wire-requested node is served by exactly one owner server.
    let served: u64 = cluster_r.servers.iter().map(|s| s.nodes_served).sum();
    assert_eq!(served, wire.nodes_requested);
    assert!(wire.resp_bytes > wire.req_bytes, "feature payloads dominate");
    // Coalescing: with 2 partitions a trainer needs at most one request
    // frame per fetch order, so frames must be far fewer than nodes.
    assert!(wire.req_frames < wire.nodes_requested);
    // The DDP barrier ran every round (epochs × max minibatches/epoch),
    // and the longest trainer was active in every one of them.
    let longest = cluster_r
        .experiment
        .per_trainer
        .iter()
        .map(|m| m.minibatches.len() as u64)
        .max()
        .unwrap();
    assert_eq!(cluster_r.allreduce_rounds, longest);
}

#[test]
fn single_trainer_cluster_runs() {
    let mut cfg = quick("fixed");
    cfg.num_trainers = 1;
    let (sim_r, cluster_r) = run_both(&cfg);
    parity_check(&sim_r, &cluster_r.experiment).unwrap();
}

/// Wall-clock overlap check: with emulated costs, prefetching must beat
/// the no-prefetch baseline.  Timing-based, so ignored by default (CI
/// runs it through the `cluster --compare-prefetch` smoke instead).
#[test]
#[ignore]
fn prefetch_beats_no_prefetch_wall_clock() {
    let cfg = quick("fixed");
    let (ds, part) = build_cluster(&cfg).unwrap();
    let ds = Arc::new(ds);
    let part = Arc::new(part);
    let mut on = ClusterConfig::new(cfg.clone());
    on.time_scale = 0.02;
    let mut off = on.clone();
    off.run.controller = ControllerSpec::NoPrefetch;
    let r_on = run_cluster_on(ds.clone(), part.clone(), &on, None).unwrap();
    let r_off = run_cluster_on(ds, part, &off, None).unwrap();
    assert!(
        r_on.wall_total < r_off.wall_total,
        "prefetch on {}s vs off {}s",
        r_on.wall_total,
        r_off.wall_total
    );
}
