//! Property-based tests (via the in-tree prop harness): coordinator
//! invariants that must hold for *every* random workload — buffer state
//! machine, sampler shapes, partitioner totality, queue discipline, JSON
//! round-trips.

use rudder::agent::parser;
use rudder::buffer::scoring::Policy;
use rudder::buffer::PersistentBuffer;
use rudder::graph::rmat::{densify_isolated, generate, RmatParams};
use rudder::partition::{partition, Method, Partition};
use rudder::sampler::Sampler;
use rudder::sim::queues::{InferencePipe, Pending};
use rudder::util::json::Json;
use rudder::util::prop::{prop_check, G};
use rudder::util::rng::Pcg32;

#[test]
fn buffer_invariants_under_random_workloads() {
    prop_check("buffer invariants", 150, |g| {
        let cap = g.usize(0, 64);
        let mut buf = PersistentBuffer::new(cap, Policy::FreqDecay);
        let rounds = g.usize(1, 40);
        for _ in 0..rounds {
            let nodes = g.vec(30, |g| g.u64(0, 200) as u32);
            let res = buf.lookup(&nodes);
            if res.hits + res.misses != nodes.len() {
                return Err("hits + misses != lookups".into());
            }
            buf.end_round();
            if g.bool() {
                let out = buf.replace();
                if out.fetched_nodes.len() != out.inserted {
                    return Err("fetched != inserted".into());
                }
            }
            if buf.len() > cap {
                return Err(format!("len {} > cap {cap}", buf.len()));
            }
            buf.check_invariants()?;
        }
        Ok(())
    });
}

#[test]
fn buffer_hits_only_for_present_nodes() {
    prop_check("lookup hit iff contained", 100, |g| {
        let cap = g.usize(1, 32);
        let mut buf = PersistentBuffer::new(cap, Policy::FreqDecay);
        // Fill with known nodes.
        let known: Vec<u32> = (0..cap as u32).collect();
        buf.prepopulate(&known);
        let probe = g.vec(20, |g| g.u64(0, 2 * cap as u64 + 1) as u32);
        let contained: Vec<bool> = probe.iter().map(|&v| buf.contains(v)).collect();
        let res = buf.lookup(&probe);
        let expected_hits = contained.iter().filter(|&&c| c).count();
        if res.hits != expected_hits {
            return Err(format!("hits {} expected {}", res.hits, expected_hits));
        }
        Ok(())
    });
}

#[test]
fn partition_totality_and_halo_disjointness() {
    prop_check("partition invariants", 25, |g| {
        let n = g.usize(20, 600) + 10;
        let edges = n * g.usize(2, 8);
        let mut rng = Pcg32::new(g.rng.next_u64());
        let csr = generate(
            &RmatParams {
                a: 0.5 + g.f64(0.0, 0.2),
                b: 0.15,
                c: 0.15,
                num_nodes: n,
                num_edges: edges,
                permute: true,
            },
            &mut rng,
        );
        let k = g.usize(1, 8).max(1);
        let method = *g.pick(&[Method::MetisLike, Method::Ldg, Method::Random]);
        let part = partition(&csr, k, method, g.rng.next_u64());
        // Totality.
        let total: usize = part.local_nodes.iter().map(Vec::len).sum();
        if total != csr.num_nodes() {
            return Err(format!("{method:?}: assigned {total}/{}", csr.num_nodes()));
        }
        // Owner consistency + halo correctness.
        for (p, locals) in part.local_nodes.iter().enumerate() {
            for &v in locals {
                if part.owner_of(v) != p {
                    return Err("owner mismatch".into());
                }
            }
            for &h in &part.halo[p] {
                if part.owner_of(h) == p {
                    return Err("halo node owned locally".into());
                }
            }
        }
        Ok(())
    });
}

#[test]
fn sampler_always_padded_and_in_range() {
    prop_check("sampler shapes", 30, |g| {
        let n = g.usize(50, 400) + 20;
        let mut rng = Pcg32::new(g.rng.next_u64());
        let csr = generate(
            &RmatParams {
                a: 0.57, b: 0.19, c: 0.19,
                num_nodes: n,
                num_edges: n * 5,
                permute: true,
            },
            &mut rng,
        );
        let csr = densify_isolated(&csr, &mut rng);
        let k = g.usize(1, 4).max(1);
        let part: Partition = partition(&csr, k, Method::Ldg, 3);
        let p = g.usize(0, k - 1);
        let f1 = g.usize(1, 6).max(1);
        let f2 = g.usize(1, 6).max(1);
        let batch = g.usize(1, 16).max(1);
        let s = Sampler::new(p, batch, f1, f2, g.rng.next_u64());
        let train = part.local_nodes[p].clone();
        if train.is_empty() {
            return Ok(());
        }
        let order = s.epoch_order(&train, 0);
        for mb in 0..s.minibatches_per_epoch(train.len()) {
            let m = s.sample(&csr, &part, &order, 0, mb);
            if m.hop1.len() != m.targets.len() * f1 {
                return Err("hop1 not dense".into());
            }
            if m.hop2.len() != m.targets.len() * f1 * f2 {
                return Err("hop2 not dense".into());
            }
            if m.hop2.iter().any(|&v| v as usize >= csr.num_nodes()) {
                return Err("sampled id out of range".into());
            }
            // local/remote split is a partition of the unique sampled set.
            for &v in &m.unique_remote {
                if part.owner_of(v) == p {
                    return Err("remote node is local".into());
                }
            }
            for &v in &m.unique_local {
                if part.owner_of(v) != p {
                    return Err("local node is remote".into());
                }
            }
        }
        Ok(())
    });
}

#[test]
fn inference_pipe_discipline() {
    prop_check("pipe state machine", 200, |g| {
        let mut pipe = InferencePipe::new();
        let mut now = 0.0f64;
        let mut in_flight: Option<f64> = None;
        for _ in 0..g.usize(1, 50) {
            now += g.f64(0.0, 2.0);
            if let Some(p) = pipe.poll(now) {
                let ready = in_flight.take().ok_or("poll returned ghost")?;
                if p.ready_at != ready {
                    return Err("wrong pending returned".into());
                }
                if ready > now {
                    return Err("returned before ready".into());
                }
            }
            if !pipe.busy() && g.bool() {
                let ready_at = now + g.f64(0.0, 3.0);
                pipe.submit(Pending {
                    issued_mb: 0,
                    issued_at: now,
                    ready_at,
                    step: rudder::agent::AgentStep {
                        action: rudder::agent::Action::Skip,
                        prediction: None,
                        latency: ready_at - now,
                        valid_response: true,
                        raw_response: String::new(),
                    },
                });
                in_flight = Some(ready_at);
            }
            if pipe.busy() != in_flight.is_some() {
                return Err("busy flag out of sync".into());
            }
        }
        Ok(())
    });
}

#[test]
fn json_roundtrip_arbitrary_values() {
    fn gen_json(g: &mut G, depth: usize) -> Json {
        if depth == 0 || g.rng.chance(0.4) {
            match g.usize(0, 3) {
                0 => Json::Null,
                1 => Json::Bool(g.bool()),
                2 => Json::Num((g.f64(-1e6, 1e6) * 100.0).round() / 100.0),
                _ => Json::Str(
                    (0..g.usize(0, 12))
                        .map(|_| *g.pick(&['a', '"', '\\', 'é', '\n', '5', ' ']))
                        .collect(),
                ),
            }
        } else if g.bool() {
            Json::Arr((0..g.usize(0, 4)).map(|_| gen_json(g, depth - 1)).collect())
        } else {
            Json::Obj(
                (0..g.usize(0, 4))
                    .map(|i| (format!("k{i}"), gen_json(g, depth - 1)))
                    .collect(),
            )
        }
    }
    prop_check("json roundtrip", 300, |g| {
        let v = gen_json(g, 4);
        for rendered in [v.to_string_compact(), v.to_string_pretty()] {
            let back = Json::parse(&rendered)
                .map_err(|e| format!("parse failed: {e} on {rendered}"))?;
            if back != v {
                return Err(format!("roundtrip mismatch: {v} vs {back}"));
            }
        }
        Ok(())
    });
}

#[test]
fn parser_never_panics_on_garbage() {
    prop_check("parser totality", 300, |g| {
        let junk: String = (0..g.usize(0, 200))
            .map(|_| *g.pick(&['{', '}', '"', ':', 'a', '\\', ',', '[', ']', ' ', '\n', '1']))
            .collect();
        let _ = parser::parse(&junk); // must not panic
        Ok(())
    });
}

#[test]
fn scoring_policy_matches_reference_semantics() {
    // Cross-check the Rust scoring policy against the python oracle's
    // documented semantics on random access patterns.
    prop_check("scoring policy", 200, |g| {
        let n = g.usize(1, 64);
        let mut scores: Vec<f32> = (0..n).map(|_| g.f64(0.0, 4.0) as f32).collect();
        let mut accessed: Vec<bool> = (0..n).map(|_| g.bool()).collect();
        let live = vec![true; n];
        let before = scores.clone();
        let was_accessed = accessed.clone();
        let stale = rudder::buffer::scoring::apply_round(&mut scores, &mut accessed, &live);
        let mut expect_stale = 0;
        for i in 0..n {
            let want = if was_accessed[i] { before[i] + 1.0 } else { before[i] * 0.95 };
            if (scores[i] - want).abs() > 1e-6 {
                return Err(format!("slot {i}: {} want {want}", scores[i]));
            }
            if scores[i] < 0.95 {
                expect_stale += 1;
            }
        }
        if stale != expect_stale {
            return Err(format!("stale {stale} want {expect_stale}"));
        }
        if accessed.iter().any(|&a| a) {
            return Err("accessed flags not cleared".into());
        }
        Ok(())
    });
}
