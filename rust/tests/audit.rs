//! Fixture suite for `rudder audit` (`src/audit/`): every rule gets a
//! bad snippet that fires (with the right rule tag and line), a good
//! snippet that stays quiet, and an `audit:allow` that suppresses — plus
//! the directive-hygiene meta rules and a self-hosting check that the
//! shipped tree audits clean.
//!
//! This file lives under `tests/`, so the self-host run sees every bad
//! fixture below as test code and (correctly) ignores it.

use std::collections::BTreeSet;

use rudder::audit::{
    check_source, default_root, run_tree, rule_names, Finding, META_MALFORMED_ALLOW,
    META_UNUSED_ALLOW,
};

fn all_rules() -> BTreeSet<&'static str> {
    rule_names().into_iter().collect()
}

/// Audit `src` as if it were the file at `path`, with every rule on.
fn audit(path: &str, src: &str) -> Vec<Finding> {
    check_source(path, src, &all_rules()).findings
}

fn assert_fires(path: &str, src: &str, rule: &str, line: usize) {
    let fs = audit(path, src);
    assert!(
        fs.iter().any(|f| f.rule == rule && f.line == line),
        "expected [{rule}] at {path}:{line}, got {fs:?}"
    );
}

fn assert_quiet(path: &str, src: &str) {
    let fs = audit(path, src);
    assert!(fs.is_empty(), "expected no findings for {path}, got {fs:?}");
}

// ---- rule 1: wall-clock-in-virtual-path --------------------------------

#[test]
fn wall_clock_bad_fires() {
    let src = "fn step() {\n    let t = Instant::now();\n}\n";
    assert_fires("src/sim/run.rs", src, "wall-clock-in-virtual-path", 2);
    assert_fires("src/cluster/prefetch.rs", src, "wall-clock-in-virtual-path", 2);
    let st = "fn f() { let t = SystemTime::now(); }\n";
    assert_fires("src/trace/mod.rs", st, "wall-clock-in-virtual-path", 1);
    // The replay engine re-emits virtual streams and must never consult
    // wall time (a wall-clocked emitter would break bit-identity).
    assert_fires("src/replay/engine.rs", src, "wall-clock-in-virtual-path", 2);
    assert_fires("src/replay/mod.rs", st, "wall-clock-in-virtual-path", 1);
}

#[test]
fn wall_clock_good_is_quiet() {
    // Virtual clocks and doc-comment mentions never fire.
    let src = "/// Unlike Instant::now(), vclock ticks are deterministic.\n\
               fn step(vclock: &mut u64) { *vclock += 1; }\n";
    assert_quiet("src/sim/run.rs", src);
    // Out-of-scope files may read the wall clock freely.
    let wall = "fn f() { let t = Instant::now(); }\n";
    assert_quiet("src/cluster/trainer.rs", wall);
}

#[test]
fn wall_clock_allow_suppresses() {
    let src = "fn f() {\n    let t = Instant::now(); \
               // audit:allow(wall-clock-in-virtual-path) RTT is wall-domain by definition\n}\n";
    let fa = check_source("src/sim/run.rs", src, &all_rules());
    assert!(fa.findings.is_empty(), "{:?}", fa.findings);
    assert_eq!(fa.suppressed, 1);
}

// ---- rule 2: unchecked-narrowing-in-codec ------------------------------

#[test]
fn narrowing_bad_fires() {
    let src = "fn put(out: &mut Vec<u8>, n: usize) {\n    \
               out.extend_from_slice(&(n as u32).to_le_bytes());\n}\n";
    assert_fires("src/cluster/wire.rs", src, "unchecked-narrowing-in-codec", 2);
    assert_fires("src/cluster/ipc.rs", src, "unchecked-narrowing-in-codec", 2);
    let u16src = "fn f(n: usize) -> u16 { n as u16 }\n";
    assert_fires("src/trace/codec.rs", u16src, "unchecked-narrowing-in-codec", 1);
}

#[test]
fn narrowing_good_is_quiet() {
    // Checked conversions, type ascriptions, and literals are all fine.
    let src = "fn put(out: &mut Vec<u8>, n: usize) -> Result<(), E> {\n    \
               let len: u32 = u32::try_from(n).map_err(|_| E)?;\n    \
               out.extend_from_slice(&len.to_le_bytes());\n    \
               let _zero = 0u32;\n    Ok(())\n}\n";
    assert_quiet("src/cluster/wire.rs", src);
    // Out of the three codec files, `as u32` is clippy's business, not ours.
    let cast = "fn f(n: usize) -> u32 { n as u32 }\n";
    assert_quiet("src/cluster/server.rs", cast);
}

#[test]
fn narrowing_allow_suppresses() {
    let src = "fn f(n: usize) -> u32 {\n    n as u32 \
               // audit:allow(unchecked-narrowing-in-codec) bounded by header validation above\n}\n";
    let fa = check_source("src/cluster/wire.rs", src, &all_rules());
    assert!(fa.findings.is_empty(), "{:?}", fa.findings);
    assert_eq!(fa.suppressed, 1);
}

// ---- rule 3: panicking-lock-in-cluster ---------------------------------

#[test]
fn panicking_lock_bad_fires() {
    let src = "fn f(m: &Mutex<u32>) {\n    let g = m.lock().unwrap();\n}\n";
    assert_fires("src/cluster/transport.rs", src, "panicking-lock-in-cluster", 2);
    let recv = "fn f(rx: &Receiver<u8>) {\n    let v = rx\n        .recv_timeout(D)\n        .unwrap();\n}\n";
    assert_fires("src/cluster/eventloop.rs", recv, "panicking-lock-in-cluster", 4);
}

#[test]
fn panicking_lock_good_is_quiet() {
    // Poison recovery, propagation, and justified expects all pass; so do
    // unwraps of non-channel results (Option math, parse, etc.).
    let src = "fn f(m: &Mutex<u32>, rx: &Receiver<u8>) -> Result<u8, E> {\n    \
               let g = m.lock().unwrap_or_else(std::sync::PoisonError::into_inner);\n    \
               let v = rx.recv().map_err(|_| E)?;\n    \
               let n = \"7\".parse::<u8>().unwrap();\n    Ok(v + n)\n}\n";
    assert_quiet("src/cluster/transport.rs", src);
    // Outside cluster/, lock-unwrap style is not this rule's business.
    let elsewhere = "fn f(m: &Mutex<u32>) { let g = m.lock().unwrap(); }\n";
    assert_quiet("src/gnn/mod.rs", elsewhere);
}

#[test]
fn panicking_lock_allow_suppresses() {
    let src = "fn f(m: &Mutex<u32>) {\n    let g = m.lock().unwrap(); \
               // audit:allow(panicking-lock-in-cluster) single-threaded setup, no poisoner exists\n}\n";
    let fa = check_source("src/cluster/run.rs", src, &all_rules());
    assert!(fa.findings.is_empty(), "{:?}", fa.findings);
    assert_eq!(fa.suppressed, 1);
}

// ---- rule 4: printing-outside-log --------------------------------------

#[test]
fn printing_bad_fires() {
    let src = "fn f() {\n    println!(\"hello\");\n}\n";
    assert_fires("src/cluster/server.rs", src, "printing-outside-log", 2);
    let e = "fn f() { eprintln!(\"oops\"); }\n";
    assert_fires("src/trace/mod.rs", e, "printing-outside-log", 1);
}

#[test]
fn printing_good_is_quiet() {
    // The logging macro itself and the allowlisted modules are exempt.
    let src = "fn f() { crate::log_info!(\"hello\"); }\n";
    assert_quiet("src/cluster/server.rs", src);
    let in_main = "fn main() { println!(\"usage: ...\"); }\n";
    assert_quiet("src/main.rs", in_main);
    assert_quiet("src/util/log.rs", "fn f() { eprintln!(\"[rudder] x\"); }\n");
}

#[test]
fn printing_allow_suppresses() {
    let src = "// audit:allow(printing-outside-log) protocol line parsed by the orchestrator\n\
               fn announce() { println!(\"RUDDER_LISTEN 1\"); }\n";
    let fa = check_source("src/cluster/multiproc.rs", src, &all_rules());
    assert!(fa.findings.is_empty(), "{:?}", fa.findings);
    assert_eq!(fa.suppressed, 1);
}

// ---- rule 5: untimed-condvar-wait --------------------------------------

#[test]
fn condvar_bad_fires() {
    let src = "use std::sync::Condvar;\nfn f(cv: &Condvar, g: G) {\n    let g = cv.wait(g);\n}\n";
    assert_fires("src/cluster/prefetch.rs", src, "untimed-condvar-wait", 3);
}

#[test]
fn condvar_good_is_quiet() {
    let src = "use std::sync::Condvar;\nfn f(cv: &Condvar, g: G) {\n    \
               let (g, _) = cv.wait_timeout(g, D).unwrap_or_else(|p| p.into_inner());\n}\n";
    assert_quiet("src/cluster/prefetch.rs", src);
    // `.wait(` on a process handle in a Condvar-free file is not a Condvar wait.
    let child = "fn f(mut c: Child) { let _ = c.wait(); }\n";
    assert_quiet("src/cluster/multiproc.rs", child);
}

#[test]
fn condvar_allow_suppresses() {
    let src = "use std::sync::Condvar;\nfn f(cv: &Condvar, g: G) {\n    let g = cv.wait(g); \
               // audit:allow(untimed-condvar-wait) notifier runs on this thread's panic path too\n}\n";
    let fa = check_source("src/cluster/prefetch.rs", src, &all_rules());
    assert!(fa.findings.is_empty(), "{:?}", fa.findings);
    assert_eq!(fa.suppressed, 1);
}

// ---- rule 6: ipc-magic-registry ----------------------------------------

#[test]
fn magic_bad_fires() {
    let src = "fn encode(out: &mut Vec<u8>) {\n    out.extend_from_slice(b\"RTR4\");\n}\n";
    assert_fires("src/cluster/ipc.rs", src, "ipc-magic-registry", 2);
    let hub = "const M: &[u8; 4] = b\"RHB2\";\n";
    assert_fires("src/cluster/eventloop.rs", hub, "ipc-magic-registry", 1);
    let trace = "fn f() -> &'static str { \"RTRC\" }\n";
    assert_fires("src/trace/codec.rs", trace, "ipc-magic-registry", 1);
}

#[test]
fn magic_good_is_quiet() {
    // Imports from the registry and longer human-readable strings pass.
    let src = "use crate::magic::IPC_TRAINER;\n\
               fn encode(out: &mut Vec<u8>) { out.extend_from_slice(IPC_TRAINER); }\n\
               fn err() -> &'static str { \"bad trace magic (want RTRC)\" }\n";
    assert_quiet("src/cluster/ipc.rs", src);
    // src/magic.rs is the registry — its own literals are the definitions.
    assert_quiet("src/magic.rs", "pub const IPC_TRAINER: &[u8; 4] = b\"RTR4\";\n");
}

#[test]
fn magic_allow_suppresses() {
    let src = "// audit:allow(ipc-magic-registry) forged stale magic for the version-skew probe\n\
               const STALE: &[u8; 4] = b\"RTR1\";\n";
    let fa = check_source("src/cluster/ipc.rs", src, &all_rules());
    assert!(fa.findings.is_empty(), "{:?}", fa.findings);
    assert_eq!(fa.suppressed, 1);
}

// ---- directive hygiene (meta rules) ------------------------------------

#[test]
fn allow_without_reason_is_malformed_and_does_not_suppress() {
    let src = "fn f() {\n    let t = Instant::now(); // audit:allow(wall-clock-in-virtual-path)\n}\n";
    let fs = audit("src/sim/run.rs", src);
    let rules: Vec<&str> = fs.iter().map(|f| f.rule).collect();
    assert!(rules.contains(&"wall-clock-in-virtual-path"), "{rules:?}");
    assert!(rules.contains(&META_MALFORMED_ALLOW), "{rules:?}");
}

#[test]
fn allow_of_unknown_rule_is_malformed() {
    let src = "// audit:allow(no-such-rule) misremembered the name\nfn f() {}\n";
    let fs = audit("src/cluster/run.rs", src);
    assert_eq!(fs.len(), 1, "{fs:?}");
    assert_eq!(fs[0].rule, META_MALFORMED_ALLOW);
}

#[test]
fn doc_comment_mention_is_not_a_directive() {
    // A rendered `audit:allow` example in rustdoc must neither suppress
    // anything nor count as a (stale) allow.
    let src = "//! e.g. `// audit:allow(printing-outside-log) announce`\nfn f() {}\n";
    let fa = check_source("src/cluster/run.rs", src, &all_rules());
    assert!(fa.findings.is_empty(), "{:?}", fa.findings);
    assert_eq!(fa.suppressed, 0);
}

#[test]
fn stale_allow_is_reported() {
    let src = "// audit:allow(printing-outside-log) this used to print\nfn f() {}\n";
    let fs = audit("src/cluster/run.rs", src);
    assert_eq!(fs.len(), 1, "{fs:?}");
    assert_eq!(fs[0].rule, META_UNUSED_ALLOW);
}

#[test]
fn own_line_allow_covers_next_code_line() {
    let src = "fn f() {\n    // audit:allow(printing-outside-log) status line for the smoke driver\n    \
               println!(\"x\");\n}\n";
    let fa = check_source("src/cluster/server.rs", src, &all_rules());
    assert!(fa.findings.is_empty(), "{:?}", fa.findings);
    assert_eq!(fa.suppressed, 1);
}

// ---- rule selection and test exemption ---------------------------------

#[test]
fn disabled_rules_do_not_fire() {
    let src = "fn f() { println!(\"x\"); let t = Instant::now(); }\n";
    let only_magic: BTreeSet<&str> = ["ipc-magic-registry"].into_iter().collect();
    let fa = check_source("src/sim/run.rs", src, &only_magic);
    assert!(fa.findings.is_empty(), "{:?}", fa.findings);
}

#[test]
fn cfg_test_region_is_exempt() {
    let src = "fn prod() {}\n\
               #[cfg(test)]\n\
               mod tests {\n    fn t(m: &Mutex<u8>) { m.lock().unwrap(); println!(\"y\"); }\n}\n";
    assert_quiet("src/cluster/transport.rs", src);
}

#[test]
fn tests_tree_is_exempt() {
    let src = "fn t(m: &Mutex<u8>) { m.lock().unwrap(); println!(\"y\"); let x = 1 as u32; }\n";
    assert_quiet("tests/cluster.rs", src);
}

// ---- self-hosting ------------------------------------------------------

/// The shipped tree must audit clean with every rule enabled: each
/// remaining wall-clock read, print, or magic literal is either fixed or
/// carries a justified `audit:allow`.  This is the same invariant the
/// blocking `audit` CI job enforces via the CLI.
#[test]
fn shipped_tree_audits_clean() {
    // `cargo test` runs with the crate as cwd; `default_root` also covers
    // invocation from the repo root.  Fall back to CARGO_MANIFEST_DIR for
    // harnesses that run the binary elsewhere.
    let root = default_root(None)
        .unwrap_or_else(|_| std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")));
    let report = run_tree(&root, &all_rules()).expect("audit pass over the real tree");
    assert!(report.files_scanned > 30, "suspiciously few files: {}", report.files_scanned);
    assert!(
        report.findings.is_empty(),
        "the shipped tree must audit clean:\n{}",
        report.render()
    );
    assert!(report.suppressed > 0, "the justified allows in cluster/ and trace/ should register");
}
