//! Property-based tests for the flight-recorder trace codec
//! (`trace::codec`), in the style of `tests/wire.rs`: arbitrary in-domain
//! event sequences must survive binary → JSONL → binary bit-exactly, and
//! truncated or corrupt prefixes must decode to clean errors, never
//! panics or junk events.

use rudder::trace::codec::{decode_binary, encode_binary, from_jsonl, to_jsonl};
use rudder::trace::{EventKind, Role, Trace, TraceEvent, TraceMeta};
use rudder::util::prop::{prop_check, G};

/// The trace integer domain: exact in an IEEE double.
const MAX_SAFE: u64 = 1 << 53;

fn arb_kind(g: &mut G) -> EventKind {
    // Biased spread over the full domain: mostly small values, sometimes
    // the 2^53 boundary itself.
    let int = |g: &mut G| -> u64 {
        if g.bool() {
            g.u64(0, 10_000)
        } else {
            *g.pick(&[0, 1, MAX_SAFE - 1, MAX_SAFE])
        }
    };
    let sec = |g: &mut G| -> f64 { g.f64(0.0, 1e6) };
    match g.usize(0, 15) {
        0 => EventKind::MinibatchBegin { epoch: g.u64(0, 100) as u32, mb: g.u64(0, 5000) as u32 },
        1 => EventKind::MinibatchEnd {
            epoch: g.u64(0, 100) as u32,
            mb: g.u64(0, 5000) as u32,
            step_vsecs: sec(g),
        },
        2 => EventKind::FetchWait { nodes: int(g), wall_secs: sec(g) },
        3 => EventKind::Compute { virtual_secs: sec(g), wall_secs: sec(g) },
        4 => EventKind::Replacement { admitted: int(g), evicted: int(g) },
        5 => EventKind::AllreduceWait { round: int(g), wall_secs: sec(g) },
        6 => EventKind::FetchIssue {
            req_id: int(g),
            owner: g.u64(0, 64) as u32,
            nodes: int(g),
            bytes: int(g),
        },
        7 => EventKind::FetchResponse { req_id: int(g), nodes: int(g), bytes: int(g) },
        8 => EventKind::Evict { nodes: int(g) },
        9 => EventKind::BatchFlush { owner: g.u64(0, 64) as u32, frames: int(g), bytes: int(g) },
        10 => EventKind::FetchServe {
            req_id: int(g),
            from: g.u64(0, 64) as u32,
            nodes: int(g),
            bytes: int(g),
        },
        11 => EventKind::AllreduceRound {
            round: int(g),
            vclock_max: sec(g),
            trainers: g.u64(1, 64) as u32,
        },
        12 => EventKind::LinkFlush { conn: g.u64(0, 32) as u32, frames: int(g), bytes: int(g) },
        13 => EventKind::ChannelClose { conn: g.u64(0, 32) as u32, channel: g.u64(0, 32) as u32 },
        14 => EventKind::SampleDemand {
            epoch: g.u64(0, 100) as u32,
            mb: g.u64(0, 5000) as u32,
            targets: int(g),
            sampled: int(g),
            remote: {
                let n = g.usize(0, 48);
                (0..n).map(|_| g.u64(0, u32::MAX as u64) as u32).collect()
            },
        },
        _ => EventKind::RoleEnd { emitted: int(g) },
    }
}

fn arb_trace(g: &mut G) -> Trace {
    let meta = TraceMeta {
        label: format!("prop-{}", g.u64(0, 999)),
        seed: g.u64(0, MAX_SAFE),
        transport: g.pick(&["channel", "tcp", "event"]).to_string(),
        compute: g.pick(&["emulated", "measured"]).to_string(),
        config: if g.bool() { format!("seed = {}\n", g.u64(0, 999)) } else { String::new() },
    };
    let mut t = Trace::new(meta);
    t.events = g.vec(64, |g| TraceEvent {
        role: *g.pick(&Role::ALL),
        id: g.u64(0, 64) as u32,
        seq: g.u64(0, MAX_SAFE),
        vclock: g.f64(0.0, 1e9),
        wall: g.f64(0.0, 1e9),
        kind: arb_kind(g),
    });
    t
}

fn assert_bit_identical(a: &Trace, b: &Trace, what: &str) -> Result<(), String> {
    if a.meta != b.meta {
        return Err(format!("{what}: meta diverged: {:?} vs {:?}", a.meta, b.meta));
    }
    if a.events.len() != b.events.len() {
        return Err(format!("{what}: {} vs {} events", a.events.len(), b.events.len()));
    }
    for (i, (ea, eb)) in a.events.iter().zip(&b.events).enumerate() {
        // PartialEq on f64 treats 0.0 == -0.0; compare through the binary
        // codec's raw-bits lens instead for true bit-exactness.
        let (ba, bb) = (
            format!("{:?} {:x} {:x}", ea.kind, ea.vclock.to_bits(), ea.wall.to_bits()),
            format!("{:?} {:x} {:x}", eb.kind, eb.vclock.to_bits(), eb.wall.to_bits()),
        );
        if (ea.role, ea.id, ea.seq) != (eb.role, eb.id, eb.seq) || ba != bb {
            return Err(format!("{what}: event {i}: {ea:?} vs {eb:?}"));
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// round-trips

#[test]
fn prop_binary_roundtrip_bit_exact() {
    prop_check("trace binary round-trip", 150, |g| {
        let t = arb_trace(g);
        let bytes = encode_binary(&t).map_err(|e| format!("encode: {e}"))?;
        let back = decode_binary(&bytes).map_err(|e| format!("decode: {e}"))?;
        assert_bit_identical(&t, &back, "binary")
    });
}

#[test]
fn prop_jsonl_roundtrip_bit_exact() {
    prop_check("trace jsonl round-trip", 150, |g| {
        let t = arb_trace(g);
        let text = to_jsonl(&t).map_err(|e| format!("to_jsonl: {e}"))?;
        let back = from_jsonl(&text).map_err(|e| format!("from_jsonl: {e}"))?;
        assert_bit_identical(&t, &back, "jsonl")
    });
}

#[test]
fn prop_binary_jsonl_binary_lossless() {
    // The full conversion cycle `rudder trace dump` performs: binary →
    // JSONL → binary must reproduce the original byte stream exactly.
    prop_check("trace binary->jsonl->binary", 150, |g| {
        let t = arb_trace(g);
        let bin1 = encode_binary(&t).map_err(|e| format!("encode: {e}"))?;
        let text = to_jsonl(&decode_binary(&bin1).map_err(|e| format!("decode: {e}"))?)
            .map_err(|e| format!("to_jsonl: {e}"))?;
        let bin2 = encode_binary(&from_jsonl(&text).map_err(|e| format!("from_jsonl: {e}"))?)
            .map_err(|e| format!("re-encode: {e}"))?;
        if bin1 != bin2 {
            return Err(format!("byte streams diverged: {} vs {} bytes", bin1.len(), bin2.len()));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// adversarial inputs

#[test]
fn prop_truncated_binary_fails_cleanly() {
    prop_check("truncated trace prefix", 150, |g| {
        let mut t = arb_trace(g);
        if t.events.is_empty() {
            t.events.push(TraceEvent {
                role: Role::Trainer,
                id: 0,
                seq: 0,
                vclock: 0.0,
                wall: 0.0,
                kind: EventKind::RoleEnd { emitted: 0 },
            });
        }
        let bytes = encode_binary(&t).map_err(|e| format!("encode: {e}"))?;
        let cut = g.usize(0, bytes.len() - 1);
        match decode_binary(&bytes[..cut]) {
            Ok(back) => {
                // A prefix that still parses must never invent events.
                if back.events.len() >= t.events.len() && cut < bytes.len() {
                    return Err(format!(
                        "truncation at {cut}/{} still yielded all {} events",
                        bytes.len(),
                        t.events.len()
                    ));
                }
                Ok(())
            }
            Err(_) => Ok(()), // clean error is the expected outcome
        }
    });
}

#[test]
fn prop_corrupt_byte_never_panics() {
    prop_check("corrupt trace byte", 200, |g| {
        let t = arb_trace(g);
        let mut bytes = encode_binary(&t).map_err(|e| format!("encode: {e}"))?;
        let i = g.usize(0, bytes.len() - 1);
        let flip = 1u8 << g.usize(0, 7);
        bytes[i] ^= flip;
        // Any outcome but a panic is acceptable; decode_binary returning
        // Ok is fine when the flipped bit lands in a float payload.
        let _ = decode_binary(&bytes);
        Ok(())
    });
}

#[test]
fn truncated_jsonl_fails_cleanly() {
    let meta = TraceMeta {
        label: "x".into(),
        seed: 7,
        transport: "channel".into(),
        compute: "emulated".into(),
        config: String::new(),
    };
    let mut t = Trace::new(meta);
    t.events.push(TraceEvent {
        role: Role::Hub,
        id: 0,
        seq: 0,
        vclock: 1.5,
        wall: 2.5,
        kind: EventKind::AllreduceRound { round: 1, vclock_max: 1.5, trainers: 2 },
    });
    let text = to_jsonl(&t).unwrap();
    // Chop mid-line: the decoder must reject, not return partial data.
    let cut = text.len() - 3;
    assert!(from_jsonl(&text[..cut]).is_err(), "chopped jsonl must not parse");
    // Missing header entirely.
    let body_only = text.lines().nth(1).unwrap();
    assert!(from_jsonl(body_only).is_err(), "jsonl without header must not parse");
}

#[test]
fn out_of_domain_events_are_rejected_at_encode() {
    let meta = TraceMeta {
        label: "dom".into(),
        seed: 1,
        transport: "channel".into(),
        compute: "emulated".into(),
        config: String::new(),
    };
    let event = |kind: EventKind, vclock: f64| TraceEvent {
        role: Role::Trainer,
        id: 0,
        seq: 0,
        vclock,
        wall: 0.0,
        kind,
    };
    // Non-finite float.
    let mut t = Trace::new(meta.clone());
    t.events.push(event(EventKind::RoleEnd { emitted: 0 }, f64::NAN));
    assert!(encode_binary(&t).is_err(), "NaN vclock must not encode");
    assert!(to_jsonl(&t).is_err(), "NaN vclock must not encode to jsonl");
    // Integer beyond 2^53.
    let mut t = Trace::new(meta);
    t.events.push(event(EventKind::Evict { nodes: (1 << 53) + 1 }, 0.0));
    assert!(encode_binary(&t).is_err(), "2^53+1 must not encode");
    assert!(to_jsonl(&t).is_err(), "2^53+1 must not encode to jsonl");
}

#[test]
fn wrong_magic_and_version_are_rejected() {
    let err = decode_binary(b"NOPE").unwrap_err().to_string();
    assert!(err.contains("magic") || err.contains("trace"), "unexpected: {err}");
    let t = Trace::new(TraceMeta {
        label: String::new(),
        seed: 0,
        transport: "channel".into(),
        compute: "emulated".into(),
        config: String::new(),
    });
    let mut bytes = encode_binary(&t).unwrap();
    bytes[4] = 0xFF; // version little-endian low byte
    assert!(decode_binary(&bytes).is_err(), "future version must be rejected");
}
