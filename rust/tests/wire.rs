//! Round-trip, adversarial, and property-based tests for the cluster wire
//! codec (`cluster::wire`) and the stream reassembly layer
//! (`cluster::transport::FrameAssembler`) — every byte between trainers,
//! feature servers, and the allreduce hub crosses a transport through this
//! format, so it gets its own integration suite in the style of
//! `tests/parsers.rs`.

use rudder::cluster::eventloop::{close_marker, encode_tagged};
use rudder::cluster::{Frame, FrameAssembler, MuxAssembler, MuxEvent};
use rudder::util::prop::{prop_check, G};

fn roundtrip(f: &Frame) -> Frame {
    let bytes = f.encode().unwrap();
    assert_eq!(bytes.len(), f.encoded_len(), "encoded_len mirror out of sync");
    let (back, used) = Frame::decode(&bytes).unwrap_or_else(|e| panic!("{f:?}: {e}"));
    assert_eq!(used, bytes.len(), "must consume the whole frame");
    back
}

// ---------------------------------------------------------------------------
// round-trips

#[test]
fn fetch_req_roundtrip() {
    for nodes in [vec![], vec![0], vec![5, 1, u32::MAX - 1], (0..1000).collect::<Vec<u32>>()] {
        let f = Frame::FetchReq { req_id: u64::MAX, from: 7, nodes };
        assert_eq!(roundtrip(&f), f);
    }
}

#[test]
fn fetch_resp_roundtrip_with_edge_floats() {
    let f = Frame::FetchResp {
        req_id: 3,
        feat_dim: 4,
        nodes: vec![10, 20],
        feats: vec![0.0, -0.0, f32::MIN_POSITIVE, f32::MAX, 1.5e-30, -7.25, 42.0, 1e30],
    };
    let Frame::FetchResp { feats, .. } = roundtrip(&f) else {
        panic!("wrong kind back")
    };
    // Bit-exact payload round-trip (including -0.0).
    let orig = match &f {
        Frame::FetchResp { feats, .. } => feats,
        _ => unreachable!(),
    };
    for (a, b) in orig.iter().zip(&feats) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

#[test]
fn allreduce_roundtrip_preserves_vclock_bits() {
    for vclock in [0.0, 1.0 / 3.0, 6.25e9, f64::MAX] {
        let f = Frame::Allreduce { part: 2, round: 99, vclock, grads: vec![1.0; 33] };
        let Frame::Allreduce { vclock: back, .. } = roundtrip(&f) else {
            panic!("wrong kind back")
        };
        assert_eq!(vclock.to_bits(), back.to_bits());
    }
}

#[test]
fn empty_payload_frames_roundtrip() {
    let f = Frame::FetchResp { req_id: 0, feat_dim: 0, nodes: vec![], feats: vec![] };
    assert_eq!(roundtrip(&f), f);
    let f = Frame::Allreduce { part: 0, round: 0, vclock: 0.0, grads: vec![] };
    assert_eq!(roundtrip(&f), f);
}

#[test]
fn hello_roundtrip() {
    for id in [0, 1, u32::MAX] {
        let f = Frame::Hello { role: 1, id };
        assert_eq!(roundtrip(&f), f);
    }
}

#[test]
fn back_to_back_frames_decode_sequentially() {
    let a = Frame::FetchReq { req_id: 1, from: 0, nodes: vec![4, 5] };
    let b = Frame::Allreduce { part: 1, round: 2, vclock: 3.5, grads: vec![0.5] };
    let mut stream = a.encode().unwrap();
    stream.extend_from_slice(&b.encode().unwrap());
    let (fa, used) = Frame::decode(&stream).unwrap();
    assert_eq!(fa, a);
    let (fb, used2) = Frame::decode(&stream[used..]).unwrap();
    assert_eq!(fb, b);
    assert_eq!(used + used2, stream.len());
}

// ---------------------------------------------------------------------------
// malformed / truncated inputs must error, never panic or over-allocate

#[test]
fn truncation_rejected_at_every_prefix_length() {
    let frames = [
        Frame::FetchReq { req_id: 7, from: 1, nodes: vec![1, 2, 3] },
        Frame::FetchResp { req_id: 7, feat_dim: 2, nodes: vec![1, 2], feats: vec![0.0; 4] },
        Frame::Allreduce { part: 0, round: 1, vclock: 2.0, grads: vec![1.0, 2.0] },
    ];
    for f in frames {
        let bytes = f.encode().unwrap();
        for cut in 0..bytes.len() {
            assert!(
                Frame::decode(&bytes[..cut]).is_err(),
                "{f:?} accepted at truncation {cut}/{}",
                bytes.len()
            );
        }
    }
}

#[test]
fn unknown_kind_rejected() {
    // Kinds 7/8 are the chunk protocol now, so the first truly-unknown
    // kind is 9.
    let mut bytes = Frame::FetchReq { req_id: 0, from: 0, nodes: vec![] }.encode().unwrap();
    for kind in [0u8, 9, 200, 255] {
        bytes[4] = kind;
        assert!(Frame::decode(&bytes).is_err(), "kind {kind} accepted");
    }
}

#[test]
fn config_roundtrip() {
    for toml in ["", "dataset = \"products\"\ntrainers = 8\n"] {
        let f = Frame::Config { toml: toml.as_bytes().to_vec() };
        assert_eq!(roundtrip(&f), f);
    }
}

#[test]
fn huge_vector_count_rejected_before_allocation() {
    // A count field claiming u32::MAX elements inside a tiny body must be
    // rejected by the length-vs-body check, not attempted.
    let mut bytes = Frame::FetchReq { req_id: 0, from: 0, nodes: vec![1] }.encode().unwrap();
    let count_at = 4 + 1 + 8 + 4; // prefix + kind + req_id + from
    bytes[count_at..count_at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
    assert!(Frame::decode(&bytes).is_err());
}

#[test]
fn trailing_garbage_inside_body_rejected() {
    // Extend the body (and its length prefix) past the last field: the
    // decoder must notice unconsumed bytes.
    let mut bytes = Frame::FetchReq { req_id: 0, from: 0, nodes: vec![9] }.encode().unwrap();
    bytes.push(0xAB);
    let body_len = (bytes.len() - 4) as u32;
    bytes[..4].copy_from_slice(&body_len.to_le_bytes());
    assert!(Frame::decode(&bytes).is_err());
}

#[test]
fn feats_nodes_dim_mismatch_rejected() {
    // Hand-build a FetchResp whose feats count disagrees with
    // nodes × feat_dim: encode a valid one, then surgically shrink the
    // feats vector count and the length prefix consistently.
    let good = Frame::FetchResp { req_id: 1, feat_dim: 3, nodes: vec![8], feats: vec![0.0; 3] };
    let mut bytes = good.encode().unwrap();
    // Drop the last f32 (4 bytes) and patch both counts.
    bytes.truncate(bytes.len() - 4);
    let feats_count_at = 4 + 1 + 8 + 4 + 4 + 4; // ... + nodes count + 1 node
    bytes[feats_count_at..feats_count_at + 4].copy_from_slice(&2u32.to_le_bytes());
    let body_len = (bytes.len() - 4) as u32;
    bytes[..4].copy_from_slice(&body_len.to_le_bytes());
    assert!(Frame::decode(&bytes).is_err(), "2 feats for 1 node × dim 3 accepted");
}

#[test]
fn oversized_body_length_rejected() {
    let mut bytes = vec![0u8; 8];
    bytes[..4].copy_from_slice(&(u32::MAX).to_le_bytes());
    assert!(Frame::decode(&bytes).is_err());
    // Zero-length body (no kind byte) is also malformed.
    let bytes = 0u32.to_le_bytes().to_vec();
    assert!(Frame::decode(&bytes).is_err());
}

#[test]
fn encode_rejects_oversized_body() {
    // Regression: `encode` used to cast lengths with `as u32` and write
    // whatever body it built — a payload past the cap (or a vector count
    // past u32::MAX) silently corrupted the length prefix and desynced
    // the whole stream.  Oversize is now an encode-time error.
    use rudder::cluster::wire::MAX_FRAME_BYTES;
    let blob = vec![0u8; MAX_FRAME_BYTES];
    let f = Frame::Result { role: 1, id: 0, blob };
    assert!(f.encode().is_err(), "Result body past MAX_FRAME_BYTES must fail to encode");
    let f = Frame::Config { toml: vec![0u8; MAX_FRAME_BYTES] };
    assert!(f.encode().is_err(), "Config body past MAX_FRAME_BYTES must fail to encode");
    // Just under the cap (body = kind + count + blob <= cap) still encodes.
    let f = Frame::Config { toml: vec![0u8; MAX_FRAME_BYTES - 8] };
    assert!(f.encode().is_ok(), "body within the cap must encode");
}

// ---------------------------------------------------------------------------
// property-based framing suite (util::prop): frames split at arbitrary
// byte boundaries, concatenated, and truncated mid-header/mid-payload must
// round-trip or error cleanly — no panic, no silent short read.

/// Random protocol frame, size-biased by the prop framework's budget.
fn gen_frame(g: &mut G) -> Frame {
    use rudder::cluster::wire::Chunk;
    match g.usize(0, 7) {
        0 => Frame::FetchReq {
            req_id: g.u64(0, 1 << 20),
            from: g.u64(0, 64) as u32,
            nodes: g.vec(48, |g| g.u64(0, 1 << 30) as u32),
        },
        1 => {
            let dim = g.usize(0, 6);
            let nodes: Vec<u32> = g.vec(24, |g| g.u64(0, 1 << 30) as u32);
            let feats: Vec<f32> =
                (0..nodes.len() * dim).map(|i| i as f32 * 0.5 - 3.25).collect();
            Frame::FetchResp { req_id: g.u64(0, 1 << 20), feat_dim: dim as u32, nodes, feats }
        }
        2 => Frame::Allreduce {
            part: g.u64(0, 64) as u32,
            round: g.u64(0, 10_000),
            vclock: g.f64(0.0, 1e6),
            grads: g.vec(48, |g| g.f64(-2.0, 2.0) as f32),
        },
        3 => Frame::Result {
            role: g.u64(1, 3) as u8,
            id: g.u64(0, 64) as u32,
            blob: g.vec(64, |g| g.u64(0, 255) as u8),
        },
        4 => Frame::Config { toml: g.vec(64, |g| g.u64(0, 255) as u8) },
        5 => Frame::ChunkReq {
            req_id: g.u64(0, 1 << 20),
            from: g.u64(0, 64) as u32,
            nodes: g.vec(32, |g| g.u64(0, 1 << 30) as u32),
            have: g.vec(12, |g| g.u64(0, 1 << 40)),
        },
        6 => {
            let dim = g.usize(1, 4);
            let n_chunks = g.usize(0, 3);
            let chunks: Vec<Chunk> = (0..n_chunks)
                .map(|_| {
                    let nodes: Vec<u32> = g.vec(8, |g| g.u64(0, 1 << 30) as u32);
                    let feats: Vec<f32> =
                        (0..nodes.len() * dim).map(|i| i as f32 * 0.25 - 1.0).collect();
                    Chunk { digest: g.u64(0, 1 << 40), nodes, feats }
                })
                .collect();
            Frame::ChunkResp {
                req_id: g.u64(0, 1 << 20),
                feat_dim: dim as u32,
                refs: g.vec(8, |g| g.u64(0, 1 << 40)),
                chunks,
            }
        }
        _ => Frame::Hello { role: 1, id: g.u64(0, 1 << 16) as u32 },
    }
}

#[test]
fn prop_random_frames_roundtrip() {
    prop_check("random frames encode/decode round-trip", 300, |g| {
        let f = gen_frame(g);
        let bytes = f.encode().map_err(|e| e.to_string())?;
        if bytes.len() != f.encoded_len() {
            return Err(format!("encoded_len {} vs {} bytes", f.encoded_len(), bytes.len()));
        }
        let (back, used) = Frame::decode(&bytes).map_err(|e| e.to_string())?;
        if used != bytes.len() {
            return Err(format!("consumed {used} of {}", bytes.len()));
        }
        if back != f {
            return Err(format!("{back:?} != {f:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_reassembly_from_arbitrary_splits() {
    prop_check("concatenated frames reassemble from arbitrary splits", 200, |g| {
        let frames: Vec<Frame> = (0..g.usize(1, 6)).map(|_| gen_frame(g)).collect();
        let mut stream = Vec::new();
        for f in &frames {
            stream.extend_from_slice(&f.encode().map_err(|e| e.to_string())?);
        }
        let mut asm = FrameAssembler::new();
        let mut out: Vec<Frame> = Vec::new();
        let mut pos = 0;
        while pos < stream.len() {
            let chunk = g.usize(1, 37).min(stream.len() - pos);
            asm.push(&stream[pos..pos + chunk]);
            pos += chunk;
            loop {
                match asm.next_frame() {
                    Ok(Some(bytes)) => {
                        let (f, used) = Frame::decode(&bytes).map_err(|e| e.to_string())?;
                        if used != bytes.len() {
                            return Err("assembler returned a partial frame".into());
                        }
                        out.push(f);
                    }
                    Ok(None) => break,
                    Err(e) => return Err(format!("mid-stream error: {e}")),
                }
            }
        }
        if asm.pending() != 0 {
            return Err(format!("{} bytes stuck in the assembler", asm.pending()));
        }
        if out != frames {
            return Err(format!("got {} frames, sent {}", out.len(), frames.len()));
        }
        Ok(())
    });
}

#[test]
fn prop_truncated_streams_pend_and_resume() {
    prop_check("truncation mid-header/mid-payload pends, then resumes", 200, |g| {
        let f = gen_frame(g);
        let bytes = f.encode().map_err(|e| e.to_string())?;
        // Any strict prefix: cuts < 4 land mid-header, larger cuts
        // mid-payload.
        let cut = g.usize(0, bytes.len() - 1);
        let mut asm = FrameAssembler::new();
        asm.push(&bytes[..cut]);
        match asm.next_frame() {
            Ok(None) => {}
            Ok(Some(_)) => return Err(format!("completed at cut {cut}/{}", bytes.len())),
            Err(e) => return Err(format!("cut {cut}: spurious error {e}")),
        }
        if asm.pending() != cut {
            return Err(format!("pending {} != cut {cut}", asm.pending()));
        }
        // Feeding the rest must recover the frame exactly — a short read
        // is never a silent short frame.
        asm.push(&bytes[cut..]);
        match asm.next_frame() {
            Ok(Some(whole)) if whole == bytes => Ok(()),
            Ok(Some(_)) => Err("resumed to different bytes".into()),
            Ok(None) => Err("complete frame still pending".into()),
            Err(e) => Err(format!("resume error: {e}")),
        }
    });
}

// ---------------------------------------------------------------------------
// event-loop mux layer (cluster::eventloop): channel-tagged frames and
// close markers must reassemble to the identical event sequence no matter
// how the stream is split across readiness wakeups, and a coalesced
// send_frames batch must be indistinguishable on the wire from per-frame
// sends.

#[test]
fn prop_mux_events_reassemble_from_arbitrary_splits() {
    prop_check("mux stream reassembles from arbitrary splits", 200, |g| {
        // A mixed schedule of tagged frames and channel-close markers over
        // a handful of logical channels, like one trainer connection under
        // `--transport event`.
        let mut events: Vec<MuxEvent> = Vec::new();
        let mut stream: Vec<u8> = Vec::new();
        for _ in 0..g.usize(1, 8) {
            let channel = g.u64(0, 5) as u32;
            if g.bool() {
                stream.extend_from_slice(&close_marker(channel));
                events.push(MuxEvent::Close(channel));
            } else {
                let frame = gen_frame(g).encode().map_err(|e| e.to_string())?;
                stream.extend_from_slice(&encode_tagged(channel, &frame));
                events.push(MuxEvent::Frame(channel, frame));
            }
        }
        let mut asm = MuxAssembler::new();
        let mut out: Vec<MuxEvent> = Vec::new();
        let mut pos = 0;
        while pos < stream.len() {
            let chunk = g.usize(1, 29).min(stream.len() - pos);
            asm.push(&stream[pos..pos + chunk]);
            pos += chunk;
            while let Some(ev) = asm.next_event().map_err(|e| e.to_string())? {
                out.push(ev);
            }
        }
        if asm.pending() != 0 {
            return Err(format!("{} bytes stuck in the mux assembler", asm.pending()));
        }
        if out != events {
            return Err(format!("got {} events, sent {}", out.len(), events.len()));
        }
        Ok(())
    });
}

#[test]
fn prop_mux_partial_tag_or_body_pends() {
    prop_check("truncated mux records pend, then resume exactly", 200, |g| {
        let channel = g.u64(0, 1 << 16) as u32;
        let frame = gen_frame(g).encode().map_err(|e| e.to_string())?;
        let bytes = encode_tagged(channel, &frame);
        // Any strict prefix: cuts < 4 land mid-channel-tag, < 8 mid-length,
        // larger cuts mid-body.
        let cut = g.usize(0, bytes.len() - 1);
        let mut asm = MuxAssembler::new();
        asm.push(&bytes[..cut]);
        match asm.next_event() {
            Ok(None) => {}
            Ok(Some(ev)) => return Err(format!("completed {ev:?} at cut {cut}/{}", bytes.len())),
            Err(e) => return Err(format!("cut {cut}: spurious error {e}")),
        }
        asm.push(&bytes[cut..]);
        match asm.next_event() {
            Ok(Some(MuxEvent::Frame(c, f))) if c == channel && f == frame => Ok(()),
            other => Err(format!("resumed to {other:?}")),
        }
    });
}

#[test]
fn prop_coalesced_batches_match_per_frame_sends() {
    use rudder::cluster::{FrameReceiver as _, FrameSender as _, LinkStatsHandle};
    use rudder::cluster::transport::{TcpFrameReceiver, TcpFrameSender};
    use std::net::{TcpListener, TcpStream};

    prop_check("send_frames batch arrives identical to per-frame sends", 30, |g| {
        let frames: Vec<Vec<u8>> =
            (0..g.usize(1, 6)).map(|_| gen_frame(g).encode().unwrap()).collect();
        let batched = g.bool();
        let listener = TcpListener::bind("127.0.0.1:0").map_err(|e| e.to_string())?;
        let addr = listener.local_addr().map_err(|e| e.to_string())?;
        let to_send = frames.clone();
        let sender = std::thread::spawn(move || -> Result<rudder::metrics::LinkStats, String> {
            let stream = TcpStream::connect(addr).map_err(|e| e.to_string())?;
            let link = LinkStatsHandle::new("batch-test");
            let mut tx = TcpFrameSender::new(stream, link.clone());
            if batched {
                tx.send_frames(&to_send).map_err(|e| e.to_string())?;
            } else {
                for f in &to_send {
                    tx.send_frame(f).map_err(|e| e.to_string())?;
                }
            }
            tx.close();
            Ok(link.snapshot())
        });
        let (stream, _) = listener.accept().map_err(|e| e.to_string())?;
        let link = LinkStatsHandle::new("batch-test");
        let mut rx = TcpFrameReceiver::new(stream, link.clone());
        let mut got: Vec<Vec<u8>> = Vec::new();
        while let Some(f) = rx.recv_frame().map_err(|e| e.to_string())? {
            got.push(f);
        }
        let sent = sender.join().map_err(|_| "sender panicked".to_string())??;
        if got != frames {
            return Err(format!("batched={batched}: {} frames back, {} sent", got.len(), frames.len()));
        }
        // Coalescing must be invisible to the counters too: one count per
        // frame on both ends, batched or not.
        let recvd = link.snapshot();
        let total: u64 = frames.iter().map(|f| f.len() as u64).sum();
        if sent.frames_sent != frames.len() as u64 || sent.bytes_sent != total {
            return Err(format!("sender counted {}f/{}B", sent.frames_sent, sent.bytes_sent));
        }
        if recvd.frames_recv != frames.len() as u64 || recvd.bytes_recv != total {
            return Err(format!("receiver counted {}f/{}B", recvd.frames_recv, recvd.bytes_recv));
        }
        Ok(())
    });
}

#[test]
fn prop_corrupt_length_prefix_errors_cleanly() {
    prop_check("corrupt length prefixes error, never panic or allocate", 200, |g| {
        let f = gen_frame(g);
        let mut bytes = f.encode().map_err(|e| e.to_string())?;
        // Invalid body length: zero, or far beyond the frame cap.
        let bad: u32 = if g.bool() { 0 } else { u32::MAX - g.u64(0, 1000) as u32 };
        bytes[..4].copy_from_slice(&bad.to_le_bytes());
        let mut asm = FrameAssembler::new();
        asm.push(&bytes);
        if asm.next_frame().is_ok() {
            return Err(format!("assembler accepted body_len {bad}"));
        }
        if Frame::decode(&bytes).is_ok() {
            return Err(format!("decoder accepted body_len {bad}"));
        }
        Ok(())
    });
}
