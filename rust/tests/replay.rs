//! Trace-driven replay (`src/replay/`): same-config re-drives must be
//! bit-identical to the recording on every in-process transport (with and
//! without the chunk cache), what-if sweeps must be deterministic down to
//! the JSON bytes, and malformed traces must be rejected cleanly.

use std::sync::Arc;

use rudder::cluster::{parity_check, run_cluster_on, ClusterConfig, ClusterResult, Transport};
use rudder::replay::{self, Overrides, SweepSpec};
use rudder::sim::{build_cluster, ControllerSpec, RunConfig};
use rudder::trace::{EventKind, Role, Trace, TraceEvent, TraceMeta};

/// Small 2-trainer config (0 time-scale: no emulation sleeps).
fn quick(controller: &str, epochs: usize) -> RunConfig {
    RunConfig {
        dataset: "ogbn-arxiv".into(),
        scale: 0.08,
        seed: 7,
        num_trainers: 2,
        batch_size: 32,
        fanout1: 5,
        fanout2: 5,
        buffer_pct: 0.25,
        epochs,
        controller: ControllerSpec::parse(controller).unwrap(),
        ..Default::default()
    }
}

/// Run the live cluster with the flight recorder on; return run + trace.
fn record(cfg: &RunConfig, transport: Transport) -> (ClusterResult, Trace) {
    let (ds, part) = build_cluster(cfg).unwrap();
    let mut ccfg = ClusterConfig::new(cfg.clone());
    ccfg.transport = transport;
    ccfg.trace = true;
    let r = run_cluster_on(Arc::new(ds), Arc::new(part), &ccfg, None).unwrap();
    let trace = r.trace.clone().expect("trace requested");
    (r, trace)
}

/// Record on `transport`, replay under the same config, and require the
/// re-emitted virtual streams (and the experiment counters) to match the
/// live run exactly.
fn identity_roundtrip(cfg: &RunConfig, transport: Transport) {
    let (live, trace) = record(cfg, transport);
    assert!(!trace.meta.config.is_empty(), "recorder must embed the config");
    let setup = replay::load(&trace).unwrap();
    let (run, report) = replay::check(&setup, &trace).unwrap();
    assert!(
        report.identical(),
        "replay diverged from the {} recording:\n{}",
        transport.name(),
        report.render()
    );
    run.trace.verify_complete().unwrap();
    parity_check(&live.experiment, &run.experiment).unwrap();
}

#[test]
fn check_bit_identity_channel() {
    // Two epochs so the epoch-boundary bookkeeping is exercised too.
    identity_roundtrip(&quick("massivegnn:8", 2), Transport::Channel);
}

#[test]
fn check_bit_identity_tcp() {
    identity_roundtrip(&quick("llm:qwen-1.5b", 1), Transport::Tcp);
}

#[test]
fn check_bit_identity_event() {
    identity_roundtrip(&quick("llm:qwen-1.5b", 1), Transport::Event);
}

#[test]
fn check_bit_identity_with_chunk_cache() {
    let mut cfg = quick("massivegnn:8", 1);
    cfg.chunk_rows = 8;
    cfg.chunk_cache_bytes = 1 << 20;
    identity_roundtrip(&cfg, Transport::Channel);
    identity_roundtrip(&cfg, Transport::Event);
}

#[test]
fn sweep_is_deterministic_to_the_byte() {
    let (_, trace) = record(&quick("massivegnn:8", 1), Transport::Channel);
    let setup = replay::load(&trace).unwrap();
    let spec = SweepSpec {
        controllers: vec![
            ControllerSpec::parse("fixed").unwrap(),
            ControllerSpec::parse("none").unwrap(),
        ],
        buffers: vec![0.05, 0.25],
        chunk_rows: None,
        chunk_cache_bytes: None,
    };
    let render = || {
        let baseline = replay::replay(&setup, &Overrides::default()).unwrap();
        let runs = replay::sweep(&setup, &spec).unwrap();
        assert_eq!(runs.len(), 4, "2 controllers x 2 buffers");
        replay::whatif_json(&setup.meta, &baseline, &runs).to_string_pretty()
    };
    let a = render();
    let b = render();
    assert_eq!(a, b, "same trace + same grid must render byte-identical JSON");
    assert!(a.contains("rudder-replay-whatif/v1"));
}

#[test]
fn whatif_overrides_change_the_outcome() {
    let (_, trace) = record(&quick("massivegnn:8", 1), Transport::Channel);
    let setup = replay::load(&trace).unwrap();
    let baseline = replay::replay(&setup, &Overrides::default()).unwrap();
    // Disabling prefetch re-fetches every remote feature on demand.
    let off = Overrides {
        controller: Some(ControllerSpec::parse("none").unwrap()),
        ..Overrides::default()
    };
    let off_run = replay::replay(&setup, &off).unwrap();
    assert_ne!(
        baseline.experiment.total_comm_nodes, off_run.experiment.total_comm_nodes,
        "a controller swap must re-drive traffic, not echo the recording"
    );
    // Enabling the chunk cache rewrites the wire protocol.
    let cached = Overrides {
        chunk_rows: Some(8),
        chunk_cache_bytes: Some(1 << 20),
        ..Overrides::default()
    };
    let cached_run = replay::replay(&setup, &cached).unwrap();
    assert!(cached_run.wire.chunks_fetched > 0, "chunk protocol must engage");
    assert_ne!(baseline.wire.resp_bytes, cached_run.wire.resp_bytes);
}

#[test]
fn truncated_and_corrupt_traces_rejected_cleanly() {
    let (_, trace) = record(&quick("massivegnn:8", 1), Transport::Channel);
    let dir = std::env::temp_dir();
    let path = dir.join(format!("rudder_replay_trunc_{}.trace", std::process::id()));
    trace.write_file(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    // Chop the binary mid-stream: must error, never panic.
    std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
    assert!(Trace::read_file(&path).is_err(), "truncated trace must not parse");
    // Arbitrary garbage likewise.
    std::fs::write(&path, b"definitely not a trace \x00\xff\x13").unwrap();
    assert!(Trace::read_file(&path).is_err(), "garbage must not parse");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn configless_trace_rejected() {
    let (_, trace) = record(&quick("massivegnn:8", 1), Transport::Channel);
    let mut stripped = trace.clone();
    stripped.meta.config.clear();
    let err = replay::load(&stripped).unwrap_err().to_string();
    assert!(err.contains("config"), "unexpected error: {err}");
}

#[test]
fn demandless_trace_rejected() {
    // A structurally complete trace (gapless stream, proper RoleEnd) that
    // simply predates demand recording must fail with a pointed message.
    let cfg = quick("massivegnn:8", 1);
    let mut t = Trace::new(TraceMeta {
        label: cfg.controller.label(),
        seed: cfg.seed,
        transport: "channel".into(),
        compute: "emulated".into(),
        config: rudder::config::to_toml(&cfg).unwrap(),
    });
    t.events.push(TraceEvent {
        role: Role::Trainer,
        id: 0,
        seq: 0,
        vclock: 0.0,
        wall: 0.0,
        kind: EventKind::RoleEnd { emitted: 0 },
    });
    let err = replay::load(&t).unwrap_err().to_string();
    assert!(err.contains("sample_demand"), "unexpected error: {err}");
}

#[test]
fn measured_trace_flagged() {
    // Only the flag matters here: is_measured() keys off the meta stamp.
    let (_, mut trace) = record(&quick("massivegnn:8", 1), Transport::Channel);
    trace.meta.compute = "measured".into();
    let setup = replay::load(&trace).unwrap();
    assert!(setup.is_measured());
}
