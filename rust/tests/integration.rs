//! Cross-module integration tests: the full pipeline (graph → partition →
//! sample → buffer → controller → metrics) under every variant, plus the
//! paper's qualitative claims at test scale.

use rudder::eval::{pass_at_1, Quality};
use rudder::partition::Method;
use rudder::sim::{build_cluster, run_on, trace_only, ControllerSpec, Mode, RunConfig};

fn cfg(controller: &str) -> RunConfig {
    RunConfig {
        dataset: "products".into(),
        scale: 0.15,
        seed: 11,
        num_trainers: 4,
        batch_size: 32,
        fanout1: 8,
        fanout2: 10,
        buffer_pct: 0.25,
        epochs: 6,
        controller: ControllerSpec::parse(controller).unwrap(),
        ..Default::default()
    }
}

#[test]
fn all_variants_run_end_to_end() {
    let base = cfg("none");
    let (ds, part) = build_cluster(&base).unwrap();
    for spec in [
        "none",
        "fixed",
        "llm:gemma3-4b",
        "llm:smollm2-360m",
        "clf:lr",
        "massivegnn:16",
        "random:0.5",
    ] {
        let mut c = cfg(spec);
        c.epochs = 3;
        let r = run_on(&ds, &part, &c, None);
        assert!(r.mean_epoch_time > 0.0, "{spec}");
        assert!(!r.per_trainer.is_empty(), "{spec}");
        let mb_count: usize = r.per_trainer.iter().map(|m| m.minibatches.len()).sum();
        assert!(mb_count > 0, "{spec}: no minibatches");
    }
}

#[test]
fn headline_claim_baseline_slowest_rudder_reduces_comm() {
    // The paper's headline: prefetching beats no-prefetch DistDGL on epoch
    // time; Rudder cuts communication by >50% at 25% buffer capacity.
    let base = cfg("none");
    let (ds, part) = build_cluster(&base).unwrap();
    let r_none = run_on(&ds, &part, &base, None);
    let r_rudder = run_on(&ds, &part, &cfg("llm:gemma3-4b"), None);
    assert!(
        r_rudder.mean_epoch_time < r_none.mean_epoch_time,
        "rudder {} vs baseline {}",
        r_rudder.mean_epoch_time,
        r_none.mean_epoch_time
    );
    let reduction = 1.0 - r_rudder.total_comm_nodes as f64 / r_none.total_comm_nodes as f64;
    assert!(reduction > 0.4, "comm reduction only {:.2}", reduction);
    assert!(r_rudder.steady_hits_pct > 40.0);
}

#[test]
fn gemma_beats_weak_models_on_pass_at_1() {
    let base = cfg("none");
    let (ds, part) = build_cluster(&base).unwrap();
    let strong = run_on(&ds, &part, &cfg("llm:gemma3-4b"), None);
    let weak = run_on(&ds, &part, &cfg("llm:smollm2-360m"), None);
    let p_strong = pass_at_1(&strong.per_trainer);
    let p_weak = pass_at_1(&weak.per_trainer);
    assert!(p_strong.trials > 0 && p_weak.trials > 0);
    assert!(
        p_strong.score > p_weak.score,
        "gemma {} <= smollm {}",
        p_strong.score,
        p_weak.score
    );
}

#[test]
fn sync_mode_stalls_and_r_is_1() {
    let base = cfg("none");
    let (ds, part) = build_cluster(&base).unwrap();
    let mut s = cfg("llm:qwen-1.5b");
    s.mode = Mode::Sync;
    s.epochs = 2;
    let mut a = s.clone();
    a.mode = Mode::Async;
    let r_sync = run_on(&ds, &part, &s, None);
    let r_async = run_on(&ds, &part, &a, None);
    assert!(r_sync.replacement_interval < 1.5);
    assert!(r_async.replacement_interval > 3.0);
    assert!(r_sync.mean_epoch_time > 3.0 * r_async.mean_epoch_time);
}

#[test]
fn trace_pipeline_feeds_classifiers() {
    let base = cfg("none");
    let (ds, part) = build_cluster(&base).unwrap();
    let set = trace_only(&ds, &part, &base);
    assert!(set.len() > 100);
    // Train and deploy an MLP with the collected traces.
    let mut c = cfg("clf:mlp");
    c.epochs = 3;
    let r = run_on(&ds, &part, &c, Some(&set));
    let decisions: usize = r.per_trainer.iter().map(|m| m.decisions.len()).sum();
    assert!(decisions > 0);
    // Classifier cadence is much faster than LLM cadence (paper Table 2).
    assert!(r.replacement_interval < 4.0, "r={}", r.replacement_interval);
}

#[test]
fn finetuned_classifier_runs_on_unseen_dataset() {
    let base = cfg("none");
    let (ds_seen, part_seen) = build_cluster(&base).unwrap();
    let set = trace_only(&ds_seen, &part_seen, &base);
    let mut c = cfg("clf:mlp:finetune=10");
    c.dataset = "yelp".into();
    c.epochs = 3;
    let (ds, part) = build_cluster(&c).unwrap();
    let r = run_on(&ds, &part, &c, Some(&set));
    assert!(r.mean_epoch_time > 0.0);
}

#[test]
fn massivegnn_warm_start_beats_cold_start_early() {
    let base = cfg("none");
    let (ds, part) = build_cluster(&base).unwrap();
    let warm = run_on(&ds, &part, &cfg("massivegnn:32"), None);
    let cold = run_on(&ds, &part, &cfg("fixed"), None);
    let early_warm = warm.per_trainer[0].minibatches[0].hits_pct;
    let early_cold = cold.per_trainer[0].minibatches[0].hits_pct;
    assert!(
        early_warm > early_cold,
        "warm {} vs cold {}",
        early_warm,
        early_cold
    );
}

#[test]
fn partition_methods_affect_comm() {
    let mut c_metis = cfg("fixed");
    c_metis.partition_method = Method::MetisLike;
    let mut c_rand = cfg("fixed");
    c_rand.partition_method = Method::Random;
    let (ds, part_m) = build_cluster(&c_metis).unwrap();
    let part_r = rudder::partition::partition(&ds.csr, 4, Method::Random, 11);
    let r_m = run_on(&ds, &part_m, &c_metis, None);
    let r_r = run_on(&ds, &part_r, &c_rand, None);
    assert!(
        r_m.total_comm_nodes < r_r.total_comm_nodes,
        "metis {} vs random {}",
        r_m.total_comm_nodes,
        r_r.total_comm_nodes
    );
}

#[test]
fn buffer_capacity_tradeoff_shape() {
    // Fig 16 shape: bigger buffers -> higher hits, lower comm.
    let base = cfg("none");
    let (ds, part) = build_cluster(&base).unwrap();
    let mut small = cfg("fixed");
    small.buffer_pct = 0.05;
    let mut large = cfg("fixed");
    large.buffer_pct = 0.25;
    let r_small = run_on(&ds, &part, &small, None);
    let r_large = run_on(&ds, &part, &large, None);
    assert!(r_large.steady_hits_pct > r_small.steady_hits_pct);
    assert!(r_large.total_comm_nodes < r_small.total_comm_nodes);
}

#[test]
fn deterministic_across_identical_runs() {
    let c = cfg("llm:llama3.2-3b");
    let (ds, part) = build_cluster(&c).unwrap();
    let a = run_on(&ds, &part, &c, None);
    let b = run_on(&ds, &part, &c, None);
    assert_eq!(a.mean_epoch_time.to_bits(), b.mean_epoch_time.to_bits());
    assert_eq!(a.total_comm_nodes, b.total_comm_nodes);
    let da: Vec<_> = a.per_trainer[0].decisions.iter().map(|d| d.replace).collect();
    let db: Vec<_> = b.per_trainer[0].decisions.iter().map(|d| d.replace).collect();
    assert_eq!(da, db);
}

#[test]
fn strong_scaling_more_trainers_fewer_minibatches_each() {
    // Remark 1: minibatches per trainer shrink as trainers grow.
    let c4 = cfg("fixed");
    let mut c8 = cfg("fixed");
    c8.num_trainers = 8;
    let (ds, part4) = build_cluster(&c4).unwrap();
    let part8 = rudder::partition::partition(&ds.csr, 8, Method::MetisLike, 11);
    let r4 = run_on(&ds, &part4, &c4, None);
    let r8 = run_on(&ds, &part8, &c8, None);
    let mb4: usize = r4.per_trainer.iter().map(|m| m.minibatches.len()).sum::<usize>() / 4;
    let mb8: usize = r8.per_trainer.iter().map(|m| m.minibatches.len()).sum::<usize>() / 8;
    assert!(mb8 < mb4, "mb8 {mb8} vs mb4 {mb4}");
}
