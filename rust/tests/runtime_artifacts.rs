//! Runtime integration: the artifact-manifest ABI through the engine.
//!
//! The default engine runs the pure-Rust interpreter backend, so these
//! tests need no artifact-build step: they load the manifest schema
//! (from disk when present, mirroring `python/compile/aot.py` otherwise)
//! and drive real forward/backward passes end to end.  The `pjrt` variant
//! at the bottom exercises the feature-gated XLA path.

use std::sync::Arc;

use rudder::classifier::mlp::RuntimeMlp;
use rudder::classifier::{DecisionModel, Kind, F};
use rudder::gnn::SageRunner;
use rudder::graph::Dataset;
use rudder::partition::{partition, Method};
use rudder::runtime::tensor as lit;
use rudder::runtime::{ArtifactConfig, Engine, Manifest};

/// Small-shape engine: fast interpreter runs, same schema as aot.py.
fn engine() -> Arc<Engine> {
    Arc::new(Engine::builtin(ArtifactConfig {
        batch: 16,
        fanout1: 3,
        fanout2: 4,
        feat_dim: 12,
        hidden: 16,
        classes: 8,
        mlp_feats: F,
        mlp_hidden: 32,
        mlp_batch: 8,
        score_block: 64,
    }))
}

#[test]
fn manifest_schema_loads_from_disk_and_matches_builtin() {
    // Write a manifest.json exactly as python/compile/aot.py emits it and
    // load it through runtime::artifacts::Manifest (the smoke-test half of
    // the python<->rust ABI contract).
    let dir = std::env::temp_dir().join(format!("rudder-rt-smoke-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let body = r#"{
      "config": {"batch": 16, "fanout1": 3, "fanout2": 4, "feat_dim": 12,
                 "hidden": 16, "classes": 8, "mlp_feats": 12, "mlp_hidden": 32,
                 "mlp_batch": 8, "score_block": 64},
      "entries": {
        "score_update": {
          "file": "score_update.hlo.txt",
          "inputs": [
            {"name": "scores", "shape": [64], "dtype": "float32"},
            {"name": "accessed", "shape": [64], "dtype": "float32"}
          ],
          "outputs": ["new_scores", "stale_mask"]
        }
      }
    }"#;
    std::fs::write(dir.join("manifest.json"), body).unwrap();
    let m = Manifest::load(&dir).unwrap();
    assert_eq!(m.config.batch, 16);
    let loaded = m.entry("score_update").unwrap();
    let builtin = Manifest::builtin(&dir, m.config.clone());
    let b = builtin.entry("score_update").unwrap();
    assert_eq!(loaded.inputs.len(), b.inputs.len());
    for (li, bi) in loaded.inputs.iter().zip(&b.inputs) {
        assert_eq!(li.shape, bi.shape);
        assert_eq!(li.dtype, bi.dtype);
    }
    assert_eq!(loaded.outputs, b.outputs);
    // And the loaded manifest executes on the interpreter (explicitly, so
    // this test stays green under `--features pjrt` without real PJRT).
    let e = Engine::load_interpreter(&dir).unwrap();
    let scores = vec![1.0f32; 64];
    let accessed = vec![0.0f32; 64];
    let out = e
        .execute(
            "score_update",
            &[
                lit::lit_f32(&[64], &scores).unwrap(),
                lit::lit_f32(&[64], &accessed).unwrap(),
            ],
        )
        .unwrap();
    assert_eq!(out.len(), 2);
}

#[test]
fn score_update_entry_matches_rust_policy() {
    let e = engine();
    let n = e.manifest.config.score_block;
    let scores: Vec<f32> = (0..n).map(|i| (i % 7) as f32 * 0.3).collect();
    let accessed: Vec<f32> = (0..n).map(|i| (i % 3 == 0) as u32 as f32).collect();
    let out = e
        .execute(
            "score_update",
            &[
                lit::lit_f32(&[n], &scores).unwrap(),
                lit::lit_f32(&[n], &accessed).unwrap(),
            ],
        )
        .unwrap();
    let new = lit::to_f32(&out[0]).unwrap();
    let stale = lit::to_f32(&out[1]).unwrap();
    // Mirror with the Rust-side policy.
    let mut rs = scores.clone();
    let mut ra: Vec<bool> = accessed.iter().map(|&a| a > 0.0).collect();
    let live = vec![true; n];
    let n_stale = rudder::buffer::scoring::apply_round(&mut rs, &mut ra, &live);
    for i in 0..n {
        assert!((new[i] - rs[i]).abs() < 1e-5, "slot {i}: rt {} rust {}", new[i], rs[i]);
    }
    assert_eq!(stale.iter().filter(|&&s| s > 0.5).count(), n_stale);
}

#[test]
fn mlp_entries_match_host_mlp() {
    let e = engine();
    let mut rt = RuntimeMlp::new(e, 1).unwrap();
    let x: [f32; F] = std::array::from_fn(|i| (i as f32 * 0.1).sin());
    // Inference parity with the host-side forward.
    let host_p = rt.weights.replace_prob(&x);
    let rt_p = rt.predict_rt(&x).unwrap();
    assert!((host_p - rt_p).abs() < 1e-4, "host {host_p} rt {rt_p}");
    // A finetune step through the engine changes the weights and reduces loss.
    let xs = vec![x; 8];
    let ys = vec![true; 8];
    let l0 = rt.finetune_rt(&xs, &ys, 0.5).unwrap();
    let mut l_last = l0;
    for _ in 0..20 {
        l_last = rt.finetune_rt(&xs, &ys, 0.5).unwrap();
    }
    assert!(l_last < l0, "loss {l0} -> {l_last}");
    let p_after = rt.predict_rt(&x).unwrap();
    assert!(p_after > host_p, "replace-prob should rise toward label 1");
}

#[test]
fn sage_train_step_learns_on_real_samples() {
    let e = engine();
    let spec = rudder::graph::datasets::by_name("ogbn-arxiv").unwrap();
    let ds = Dataset::build(spec, 0.1, 3);
    let part = partition(&ds.csr, 2, Method::MetisLike, 1);
    let c = e.manifest.config.clone();
    let sampler = rudder::sampler::Sampler::new(0, c.batch, c.fanout1, c.fanout2, 5);
    let train = part.train_nodes_of(0, &ds.train_nodes);
    let order = sampler.epoch_order(&train, 0);
    let mut runner = SageRunner::new(e, 7, 0.05);
    let mb = sampler.sample(&ds.csr, &part, &order, 0, 0);
    assert!(!mb.targets.is_empty());
    let (first, _) = runner.train_step(&mb, ds.feature_seed, &ds.labels).unwrap();
    let mut last = first;
    for _ in 0..15 {
        let (l, dt) = runner.train_step(&mb, ds.feature_seed, &ds.labels).unwrap();
        last = l;
        assert!(dt >= 0.0);
    }
    assert!(
        last < first * 0.9,
        "repeated steps on one batch must overfit: {first} -> {last}"
    );
    // Forward-only evaluation returns a sane accuracy.
    let acc = runner.eval_accuracy(&mb, ds.feature_seed, &ds.labels).unwrap();
    assert!((0.0..=1.0).contains(&acc));
}

#[test]
fn engine_rejects_bad_abi() {
    let e = engine();
    // Wrong arity.
    assert!(e.execute("score_update", &[]).is_err());
    // Unknown entry.
    assert!(e
        .execute("nonexistent_entry", &[lit::lit_scalar_f32(0.0).unwrap()])
        .is_err());
    // Wrong shape.
    let short = vec![0.0f32; 3];
    let bad = lit::lit_f32(&[3], &short).unwrap();
    assert!(e.execute("score_update", &[bad.clone(), bad]).is_err());
}

#[test]
fn engine_timing_accounting() {
    let e = engine();
    let n = e.manifest.config.score_block;
    let zeros = vec![0.0f32; n];
    let inputs = [
        lit::lit_f32(&[n], &zeros).unwrap(),
        lit::lit_f32(&[n], &zeros).unwrap(),
    ];
    let (c0, _) = e.timing("score_update");
    e.execute("score_update", &inputs).unwrap();
    e.execute("score_update", &inputs).unwrap();
    let (c1, total) = e.timing("score_update");
    assert_eq!(c1 - c0, 2);
    assert!(total >= 0.0);
    assert!(e.mean_latency("score_update").unwrap() >= 0.0);
}

#[test]
fn runtime_mlp_composes_with_decision_models() {
    let e = engine();
    // The host-side RustMlp and the runtime path share weights layout;
    // sanity check the DecisionModel plumbing end to end.
    let mut rust_mlp = Kind::Mlp.build(3);
    let xs: Vec<[f32; F]> = (0..64)
        .map(|i| std::array::from_fn(|j| ((i * j) as f32 * 0.07).cos()))
        .collect();
    let ys: Vec<bool> = xs.iter().map(|x| x[0] > 0.0).collect();
    rust_mlp.fit(&xs, &ys);
    let acc = rust_mlp.accuracy(&xs, &ys);
    assert!(acc > 0.8, "{acc}");
    drop(e);
}

/// The PJRT path needs real artifacts + the real xla crate patched in, so
/// it is ignored by default; `cargo test --features pjrt -- --ignored`
/// exercises it (against the vendored stub it must fail with a clear
/// "PJRT runtime not linked" error rather than compile breakage).
#[cfg(feature = "pjrt")]
#[test]
#[ignore = "requires real PJRT runtime + built artifacts (python -m compile.aot)"]
fn pjrt_backend_loads_artifacts() {
    let dir = Manifest::default_dir();
    match Engine::load_pjrt(&dir) {
        Ok(e) => {
            let n = e.manifest.config.score_block;
            let zeros = vec![0.0f32; n];
            let out = e.execute(
                "score_update",
                &[
                    lit::lit_f32(&[n], &zeros).unwrap(),
                    lit::lit_f32(&[n], &zeros).unwrap(),
                ],
            );
            assert!(out.is_ok() || out.is_err()); // exercised either way
        }
        Err(e) => {
            let msg = e.to_string();
            assert!(
                msg.contains("pjrt") || msg.contains("PJRT") || msg.contains("artifacts"),
                "unexpected pjrt load error: {msg}"
            );
        }
    }
}
