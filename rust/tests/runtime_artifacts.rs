//! Runtime integration: AOT artifacts through the PJRT engine.
//!
//! These tests require `make artifacts` to have run; they skip (with a
//! note) otherwise so `cargo test` stays green in a fresh checkout.

use std::sync::Arc;

use rudder::classifier::mlp::XlaMlp;
use rudder::classifier::{DecisionModel, Kind, F};
use rudder::gnn::XlaRunner;
use rudder::graph::Dataset;
use rudder::partition::{partition, Method};
use rudder::runtime::{literal as lit, Engine};
use rudder::sampler::Sampler;

fn engine() -> Option<Arc<Engine>> {
    Engine::try_load_default().map(Arc::new)
}

macro_rules! require_engine {
    () => {
        match engine() {
            Some(e) => e,
            None => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn score_update_artifact_matches_rust_policy() {
    let e = require_engine!();
    let n = e.manifest.config.score_block;
    let scores: Vec<f32> = (0..n).map(|i| (i % 7) as f32 * 0.3).collect();
    let accessed: Vec<f32> = (0..n).map(|i| (i % 3 == 0) as u32 as f32).collect();
    let out = e
        .execute(
            "score_update",
            &[
                lit::lit_f32(&[n], &scores).unwrap(),
                lit::lit_f32(&[n], &accessed).unwrap(),
            ],
        )
        .unwrap();
    let new = lit::to_f32(&out[0]).unwrap();
    let stale = lit::to_f32(&out[1]).unwrap();
    // Mirror with the Rust-side policy.
    let mut rs = scores.clone();
    let mut ra: Vec<bool> = accessed.iter().map(|&a| a > 0.0).collect();
    let live = vec![true; n];
    let n_stale = rudder::buffer::scoring::apply_round(&mut rs, &mut ra, &live);
    for i in 0..n {
        assert!((new[i] - rs[i]).abs() < 1e-5, "slot {i}: xla {} rust {}", new[i], rs[i]);
    }
    assert_eq!(stale.iter().filter(|&&s| s > 0.5).count(), n_stale);
}

#[test]
fn mlp_artifacts_match_host_mlp() {
    let e = require_engine!();
    let mut xla = XlaMlp::new(e, 1).unwrap();
    let x: [f32; F] = std::array::from_fn(|i| (i as f32 * 0.1).sin());
    // Inference parity with the host-side forward.
    let host_p = xla.weights.replace_prob(&x);
    let xla_p = xla.predict_xla(&x).unwrap();
    assert!((host_p - xla_p).abs() < 1e-4, "host {host_p} xla {xla_p}");
    // A finetune step through PJRT changes the weights and reduces loss.
    let xs = vec![x; 8];
    let ys = vec![true; 8];
    let l0 = xla.finetune_xla(&xs, &ys, 0.5).unwrap();
    let mut l_last = l0;
    for _ in 0..20 {
        l_last = xla.finetune_xla(&xs, &ys, 0.5).unwrap();
    }
    assert!(l_last < l0, "loss {l0} -> {l_last}");
    let p_after = xla.predict_xla(&x).unwrap();
    assert!(p_after > host_p, "replace-prob should rise toward label 1");
}

#[test]
fn sage_train_step_learns_on_real_samples() {
    let e = require_engine!();
    let spec = rudder::graph::datasets::by_name("ogbn-arxiv").unwrap();
    let ds = Dataset::build(spec, 0.2, 3);
    let part = partition(&ds.csr, 2, Method::MetisLike, 1);
    let c = e.manifest.config.clone();
    let sampler = Sampler::new(0, c.batch, c.fanout1, c.fanout2, 5);
    let train = part.train_nodes_of(0, &ds.train_nodes);
    let order = sampler.epoch_order(&train, 0);
    let mut runner = XlaRunner::new(e, 7, 0.05);
    let mb = sampler.sample(&ds.csr, &part, &order, 0, 0);
    assert!(!mb.targets.is_empty());
    let (first, _) = runner.train_step(&mb, ds.feature_seed, &ds.labels).unwrap();
    let mut last = first;
    for _ in 0..15 {
        let (l, dt) = runner.train_step(&mb, ds.feature_seed, &ds.labels).unwrap();
        last = l;
        assert!(dt > 0.0);
    }
    assert!(
        last < first * 0.9,
        "repeated steps on one batch must overfit: {first} -> {last}"
    );
}

#[test]
fn engine_rejects_bad_abi() {
    let e = require_engine!();
    // Wrong arity.
    assert!(e.execute("score_update", &[]).is_err());
    // Unknown entry.
    assert!(e
        .execute("nonexistent_entry", &[lit::lit_scalar_f32(0.0).unwrap()])
        .is_err());
}

#[test]
fn engine_timing_accounting() {
    let e = require_engine!();
    let n = e.manifest.config.score_block;
    let zeros = vec![0.0f32; n];
    let inputs = [
        lit::lit_f32(&[n], &zeros).unwrap(),
        lit::lit_f32(&[n], &zeros).unwrap(),
    ];
    let (c0, _) = e.timing("score_update");
    e.execute("score_update", &inputs).unwrap();
    e.execute("score_update", &inputs).unwrap();
    let (c1, total) = e.timing("score_update");
    assert_eq!(c1 - c0, 2);
    assert!(total > 0.0);
    assert!(e.mean_latency("score_update").unwrap() > 0.0);
}

#[test]
fn xla_mlp_classifier_usable_as_decision_model() {
    let e = require_engine!();
    // The host-side RustMlp and the XLA path share weights layout; sanity
    // check the DecisionModel plumbing end to end on synthetic data.
    let mut rust_mlp = Kind::Mlp.build(3);
    let xs: Vec<[f32; F]> = (0..64)
        .map(|i| std::array::from_fn(|j| ((i * j) as f32 * 0.07).cos()))
        .collect();
    let ys: Vec<bool> = xs.iter().map(|x| x[0] > 0.0).collect();
    rust_mlp.fit(&xs, &ys);
    let acc = rust_mlp.accuracy(&xs, &ys);
    assert!(acc > 0.8, "{acc}");
    drop(e);
}
