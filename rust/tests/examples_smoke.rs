//! Smoke coverage for the documented entry points under `examples/`.
//!
//! `cargo test` already compiles every example (so they cannot rot at the
//! type level); this suite additionally *runs* the quickstart flow — the
//! same build_cluster → three-variant comparison — at micro scale, so the
//! README's first command keeps working behaviorally.  CI runs the real
//! `cargo run --release --example quickstart` on top.

use rudder::sim::{build_cluster, run_on, ControllerSpec, RunConfig};

/// Micro version of examples/quickstart.rs: same call sequence, tiny run.
#[test]
fn quickstart_flow_runs_all_three_variants() {
    let mut cfg = RunConfig {
        dataset: "products".into(),
        scale: 0.05,
        num_trainers: 2,
        buffer_pct: 0.25,
        epochs: 3,
        batch_size: 16,
        fanout1: 4,
        fanout2: 4,
        ..Default::default()
    };
    let (ds, part) = build_cluster(&cfg).expect("cluster build");
    assert!(ds.csr.num_nodes() > 0);
    let mut comms = Vec::new();
    for spec in ["none", "fixed", "llm:gemma3-4b"] {
        cfg.controller = ControllerSpec::parse(spec).expect("controller spec");
        let r = run_on(&ds, &part, &cfg, None);
        assert!(r.mean_epoch_time > 0.0, "{spec}: no epoch time");
        assert!(
            r.per_trainer.iter().any(|m| !m.minibatches.is_empty()),
            "{spec}: no minibatches ran"
        );
        comms.push((spec, r.total_comm_nodes));
    }
    // The quickstart's headline row: buffered variants fetch fewer remote
    // nodes than the no-prefetch baseline.
    let base = comms[0].1;
    for &(spec, comm) in &comms[1..] {
        assert!(comm < base, "{spec}: comm {comm} !< baseline {base}");
    }
}

/// The e2e example's core path: a real runtime train step composes with
/// the sampler on the default engine (interpreter backend).
#[test]
fn e2e_train_core_path_composes() {
    use rudder::gnn::SageRunner;
    use rudder::runtime::{ArtifactConfig, Engine};
    use std::sync::Arc;

    let engine = Arc::new(Engine::builtin(ArtifactConfig {
        batch: 8,
        fanout1: 3,
        fanout2: 3,
        feat_dim: 10,
        hidden: 12,
        classes: 6,
        ..Default::default()
    }));
    let cfg = RunConfig {
        dataset: "ogbn-arxiv".into(),
        scale: 0.1,
        num_trainers: 2,
        epochs: 1,
        batch_size: 8,
        fanout1: 3,
        fanout2: 3,
        ..Default::default()
    };
    let (ds, part) = build_cluster(&cfg).unwrap();
    let art = engine.manifest.config.clone();
    let sampler = rudder::sampler::Sampler::new(0, art.batch, art.fanout1, art.fanout2, 1234);
    let train0 = part.train_nodes_of(0, &ds.train_nodes);
    assert!(!train0.is_empty());
    let order = sampler.epoch_order(&train0, 0);
    let mut runner = SageRunner::new(engine, 7, 0.05);
    let mb = sampler.sample(&ds.csr, &part, &order, 0, 0);
    let (loss, dt) = runner.train_step(&mb, ds.feature_seed, &ds.labels).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    assert!(dt >= 0.0);
}
