//! `cargo bench --bench paper_benches` — regenerates every table and
//! figure of the paper's evaluation (§5) at Quick quality, printing the
//! same rows/series the paper reports plus wall time per experiment.
//!
//! Absolute numbers come from the simulated testbed (DESIGN.md §2); the
//! *shape* — who wins, by what factor, where crossovers fall — is the
//! reproduction target.  CSVs are written under `results/`.
//!
//! Run one experiment: `cargo bench --bench paper_benches -- fig12`

use rudder::eval::harness::{run_experiment_id, EXPERIMENTS};
use rudder::eval::Quality;

fn main() {
    let filter: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| !a.starts_with('-'))
        .collect();
    let quality = if std::env::var("RUDDER_BENCH_FULL").is_ok() {
        Quality::Full
    } else {
        Quality::Quick
    };
    let ids: Vec<&str> = EXPERIMENTS
        .iter()
        .copied()
        .filter(|id| filter.is_empty() || filter.iter().any(|f| id.contains(f.as_str())))
        .collect();
    println!("paper-reproduction bench: {} experiments at {quality:?}\n", ids.len());
    let mut failures = 0;
    let t_all = std::time::Instant::now();
    for id in ids {
        println!("───────────────────────────────────────────────────────────");
        let t0 = std::time::Instant::now();
        match run_experiment_id(id, quality) {
            Ok(tables) => {
                for t in tables {
                    t.emit(&format!("bench_{id}"));
                }
                println!("[{id}: {:.1}s]", t0.elapsed().as_secs_f64());
            }
            Err(e) => {
                eprintln!("[{id} FAILED: {e}]");
                failures += 1;
            }
        }
    }
    println!(
        "\nall experiments done in {:.1}s ({failures} failures)",
        t_all.elapsed().as_secs_f64()
    );
    if failures > 0 {
        std::process::exit(1);
    }
}
