//! `cargo bench --bench measured_compute` — the measured-compute hot
//! path: what one cluster trainer pays per minibatch when `--compute
//! measured` replaces emulation sleeps with real work.
//!
//! Three stages, benchmarked separately so regressions localize:
//!
//! 1. minibatch → tensor packing with seeded feature synthesis (the sim /
//!    e2e path),
//! 2. the same packing gathering rows from a resident map (the cluster
//!    trainer's FeatureStore-gather path),
//! 3. the full `sage_train_step` through the interpreter backend (fwd +
//!    bwd + update — the T_DDP the BENCH harness measures end to end).
//!
//! `-- --smoke` runs every stage once and exits: CI executes that so the
//! bench code cannot silently rot (`cargo bench --no-run` only proves it
//! compiles).

use std::collections::HashMap;
use std::hint::black_box;
use std::sync::Arc;
use std::time::Instant;

use rudder::gnn::assemble::{pack_minibatch, pack_minibatch_with};
use rudder::gnn::{SageRunner, SageShape};
use rudder::graph::features::fill_features;
use rudder::graph::Dataset;
use rudder::runtime::{ArtifactConfig, Engine};
use rudder::sampler::Sampler;

struct Bench {
    rows: Vec<(String, f64, u64)>,
    iters: u64,
}

impl Bench {
    fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) {
        for _ in 0..(self.iters / 10).min(3) {
            black_box(f()); // warmup
        }
        let t0 = Instant::now();
        for _ in 0..self.iters {
            black_box(f());
        }
        let per = t0.elapsed().as_secs_f64() / self.iters as f64;
        self.rows.push((name.to_string(), per, self.iters));
    }

    fn report(&self) {
        println!("\n== measured-compute microbenchmarks ==");
        println!("{:<52} {:>12} {:>8}", "benchmark", "per-op", "iters");
        println!("{}", "-".repeat(76));
        for (name, per, iters) in &self.rows {
            let t = if *per >= 1e-3 {
                format!("{:.3} ms", per * 1e3)
            } else {
                format!("{:.2} µs", per * 1e6)
            };
            println!("{name:<52} {t:>12} {iters:>8}");
        }
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut b = Bench { rows: Vec::new(), iters: if smoke { 1 } else { 20 } };

    // The pinned BENCH_cluster shape: ogbn-arxiv features, small fanouts.
    let ds = Dataset::build_by_name("ogbn-arxiv", 0.1, 7).expect("dataset");
    let part = rudder::partition::partition(&ds.csr, 2, rudder::partition::Method::MetisLike, 1);
    let shape = SageShape {
        batch: 32,
        fanout1: 5,
        fanout2: 5,
        feat_dim: ds.spec.feat_dim,
        hidden: 128,
        classes: ds.spec.num_classes,
    };
    let sampler = Sampler::new(0, shape.batch, shape.fanout1, shape.fanout2, 7);
    let train = part.train_nodes_of(0, &ds.train_nodes);
    let order = sampler.epoch_order(&train, 0);
    let mb = sampler.sample(&ds.csr, &part, &order, 0, 0);
    assert!(!mb.targets.is_empty(), "bench minibatch must have work");

    // 1. Seeded synthesis packing.
    b.run("pack_minibatch (seeded synthesis)", || {
        pack_minibatch(&shape, &mb, ds.feature_seed, &ds.labels).expect("pack")
    });

    // 2. Resident-map gather packing (the FeatureStore path's cost shape:
    //    hash lookup + row copy per node).
    let mut resident: HashMap<u32, Box<[f32]>> = HashMap::new();
    for &n in mb.targets.iter().chain(&mb.hop1).chain(&mb.hop2) {
        resident.entry(n).or_insert_with(|| {
            let mut row = vec![0.0f32; shape.feat_dim];
            fill_features(ds.feature_seed, n, &mut row);
            row.into_boxed_slice()
        });
    }
    b.run("pack_minibatch_with (resident-map gather)", || {
        pack_minibatch_with(&shape, &mb, &ds.labels, |n, dst| {
            dst.copy_from_slice(&resident[&n]);
        })
        .expect("pack")
    });

    // 3. The real train step (interpreter backend), exactly as a measured
    //    cluster trainer runs it.
    let engine = Arc::new(Engine::builtin(ArtifactConfig {
        batch: shape.batch,
        fanout1: shape.fanout1,
        fanout2: shape.fanout2,
        feat_dim: shape.feat_dim,
        hidden: shape.hidden,
        classes: shape.classes,
        ..ArtifactConfig::default()
    }));
    let mut runner = SageRunner::new(engine, 7, 0.05);
    b.run("sage_train_step (interpreter fwd+bwd+update)", || {
        let step = runner.train_step(&mb, ds.feature_seed, &ds.labels);
        step.expect("train step")
    });
    let losses = &runner.losses;
    assert!(losses.iter().all(|l| l.is_finite()), "measured step produced non-finite loss");

    b.report();
    if smoke {
        println!("smoke OK: every measured-compute stage executed once");
    }
}
