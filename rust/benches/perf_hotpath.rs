//! `cargo bench --bench perf_hotpath` — L3 hot-path microbenchmarks.
//!
//! The §Perf targets (EXPERIMENTS.md): the coordinator must never be the
//! bottleneck — per-minibatch L3 work (sample + lookup + score pass +
//! prompt build) must stay ≪ 1 ms, i.e. orders of magnitude below T_DDP.

use std::hint::black_box;
use std::time::Instant;

use rudder::agent::{prompt, Observation};
use rudder::buffer::scoring::Policy;
use rudder::buffer::PersistentBuffer;
use rudder::graph::rmat::{densify_isolated, generate, RmatParams};
use rudder::graph::Dataset;
use rudder::partition::{partition, Method};
use rudder::sampler::Sampler;
use rudder::util::json::Json;
use rudder::util::rng::Pcg32;

struct Bench {
    rows: Vec<(String, f64, u64)>,
}

impl Bench {
    fn run<T>(&mut self, name: &str, iters: u64, mut f: impl FnMut() -> T) {
        // Warmup.
        for _ in 0..iters / 10 + 1 {
            black_box(f());
        }
        let t0 = Instant::now();
        for _ in 0..iters {
            black_box(f());
        }
        let per = t0.elapsed().as_secs_f64() / iters as f64;
        self.rows.push((name.to_string(), per, iters));
    }

    fn report(&self) {
        println!("\n== L3 hot-path microbenchmarks ==");
        println!("{:<44} {:>12} {:>10}", "benchmark", "per-op", "iters");
        println!("{}", "-".repeat(70));
        for (name, per, iters) in &self.rows {
            let t = if *per >= 1e-3 {
                format!("{:.3} ms", per * 1e3)
            } else if *per >= 1e-6 {
                format!("{:.2} µs", per * 1e6)
            } else {
                format!("{:.0} ns", per * 1e9)
            };
            println!("{name:<44} {t:>12} {iters:>10}");
        }
    }
}

fn main() {
    let mut b = Bench { rows: Vec::new() };

    // --- graph + partition setup (not timed) -----------------------------
    let mut rng = Pcg32::new(1);
    let csr = generate(
        &RmatParams {
            a: 0.57, b: 0.19, c: 0.19,
            num_nodes: 20_000,
            num_edges: 200_000,
            permute: true,
        },
        &mut rng,
    );
    let csr = densify_isolated(&csr, &mut rng);
    let part = partition(&csr, 4, Method::MetisLike, 1);

    // --- sampler ---------------------------------------------------------
    let sampler = Sampler::new(0, 256, 10, 25, 7);
    let train = part.local_nodes[0].clone();
    let order = sampler.epoch_order(&train, 0);
    let mut mb_i = 0usize;
    b.run("sampler: 2-hop minibatch (256×10×25)", 200, || {
        mb_i = (mb_i + 1) % sampler.minibatches_per_epoch(train.len());
        sampler.sample(&csr, &part, &order, 0, mb_i)
    });
    let mb = sampler.sample(&csr, &part, &order, 0, 0);

    // --- buffer ----------------------------------------------------------
    let mut buf = PersistentBuffer::new(4096, Policy::FreqDecay);
    buf.prepopulate(&mb.unique_remote);
    b.run("buffer: lookup (sampled remote set)", 2_000, || {
        buf.lookup(&mb.unique_remote)
    });
    b.run("buffer: score pass (end_round, 4096 slots)", 2_000, || buf.end_round());
    b.run("buffer: replacement round", 500, || {
        buf.lookup(&mb.unique_remote);
        buf.end_round();
        buf.replace()
    });

    // --- agent path --------------------------------------------------------
    let obs = Observation {
        hits_pct: 63.2,
        buffer_occupancy_pct: 88.0,
        stale_pct: 7.5,
        comm_nodes_last: 1800,
        comm_nodes_ema: 1750.0,
        minibatches_done: 120,
        minibatches_pending: 360,
        graph_nodes: 20_000,
        graph_edges: 100_000,
        halo_nodes: 4_000,
        buffer_capacity: 1_000,
        ..Default::default()
    };
    let history: Vec<_> = (0..16)
        .map(|i| rudder::agent::context::HistoryEntry {
            minibatch: i,
            action: rudder::agent::Action::Skip,
            predicted: Some(rudder::metrics::HitsPrediction::Unchanged),
            hits_before: 60.0,
            hits_after: Some(61.0),
            comm_before: 1800.0,
            comm_after: Some(1700.0),
            outcome_pass: Some(true),
        })
        .collect();
    b.run("agent: prompt build (16-entry history)", 2_000, || {
        prompt::build(&obs, &history)
    });
    let prompt_text = prompt::build(&obs, &history);
    b.run("agent: simulated-LLM decision", 2_000, || {
        use rudder::agent::backend::{LlmBackend, SimulatedLlm};
        let mut llm = SimulatedLlm::new(
            rudder::agent::profiles::by_name("gemma3-4b").unwrap(),
            1,
            false,
        );
        llm.complete(&prompt_text)
    });
    let reply = r#"{"action": "replace", "expected_hits": "increase", "reason": "low hits"}"#;
    b.run("agent: response parse", 20_000, || {
        rudder::agent::parser::parse(reply)
    });

    // --- classifier inference ---------------------------------------------
    let (xs, ys) = {
        let mut rng = Pcg32::new(9);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..400 {
            let x: [f32; rudder::classifier::F] = std::array::from_fn(|_| rng.f32());
            ys.push(x[0] > 0.5);
            xs.push(x);
        }
        (xs, ys)
    };
    for kind in rudder::classifier::ALL_KINDS {
        let mut m = kind.build(1);
        m.fit(&xs, &ys);
        b.run(&format!("classifier: {} inference", kind.name()), 20_000, || {
            m.predict(&xs[0])
        });
    }

    // --- util substrates ---------------------------------------------------
    let doc = Json::obj(vec![
        ("hits", Json::num(63.2)),
        ("history", Json::Arr((0..16).map(|i| Json::num(i as f64)).collect())),
    ])
    .to_string_pretty();
    b.run("json: parse observation-sized doc", 50_000, || Json::parse(&doc));

    // --- full simulation throughput ---------------------------------------
    let spec = rudder::graph::datasets::by_name("ogbn-arxiv").unwrap();
    let ds = Dataset::build(spec, 0.1, 1);
    let cfg = rudder::sim::RunConfig {
        dataset: "ogbn-arxiv".into(),
        scale: 0.1,
        num_trainers: 4,
        batch_size: 32,
        fanout1: 5,
        fanout2: 5,
        epochs: 2,
        controller: rudder::sim::ControllerSpec::parse("llm:gemma3-4b").unwrap(),
        ..Default::default()
    };
    let part2 = partition(&ds.csr, 4, Method::MetisLike, 1);
    b.run("sim: full 2-epoch 4-trainer run", 10, || {
        rudder::sim::run_on(&ds, &part2, &cfg, None)
    });

    b.report();

    // Per-minibatch L3 budget check (the §Perf target).
    let l3_per_mb: f64 = b
        .rows
        .iter()
        .filter(|(n, _, _)| {
            n.starts_with("sampler") || n.starts_with("buffer: lookup")
                || n.starts_with("buffer: score")
        })
        .map(|(_, per, _)| per)
        .sum();
    println!(
        "\nL3 per-minibatch critical path ≈ {:.1} µs ({}× under the 1 ms budget)",
        l3_per_mb * 1e6,
        (1e-3 / l3_per_mb) as u64
    );
}
