"""Tiled Pallas matmul kernel.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid walks (M/bm, N/bn)
output tiles; grid axis 2 runs the K reduction in bk chunks, keeping one
(bm, bk) activation tile and one (bk, bn) weight tile resident in VMEM while
the MXU consumes them.  ``BlockSpec`` expresses the HBM->VMEM schedule the
paper's GPU code did with threadblocks + shared memory.  VMEM budget per
step = bm*bk + bk*bn + bm*bn floats; the default (128, 128, 128) tiles use
192 KiB @ f32 -- far under the 16 MiB VMEM ceiling, leaving headroom for
double-buffering.  128x128 tiles map 1:1 onto the MXU systolic array.

Interpret mode executes the same schedule with numpy semantics so the HLO we
AOT-export runs on the CPU PJRT client.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    """One (bm, bn) output tile; grid axis 2 runs the K reduction."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pad_to(x: jax.Array, m: int, axis: int) -> jax.Array:
    rem = x.shape[axis] % m
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, m - rem)
    return jnp.pad(x, pad)


def _matmul_pallas(
    x: jax.Array,
    w: jax.Array,
    block_m: int,
    block_n: int,
    block_k: int,
) -> jax.Array:
    m, k = x.shape
    _, n = w.shape
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(w, bk, 0), bn, 1)
    mp, kp = xp.shape
    np_ = wp.shape[1]
    n_k = kp // bk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=(mp // bm, np_ // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
    )(xp, wp)
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _matmul(x, w, block_m, block_n, block_k):
    return _matmul_pallas(x, w, block_m, block_n, block_k)


def _matmul_fwd(x, w, block_m, block_n, block_k):
    return _matmul_pallas(x, w, block_m, block_n, block_k), (x, w)


def _matmul_bwd(block_m, block_n, block_k, res, g):
    # Standard matmul transpose rule in plain jnp (flash-attention-style
    # split: Pallas fwd, jnp bwd) so L2 train steps can grad through it.
    x, w = res
    g32 = g.astype(jnp.float32)
    dx = (g32 @ w.astype(jnp.float32).T).astype(x.dtype)
    dw = (x.astype(jnp.float32).T @ g32).astype(w.dtype)
    return dx, dw


_matmul.defvjp(_matmul_fwd, _matmul_bwd)


def matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """``x @ w`` via the tiled Pallas kernel (differentiable).

    Arbitrary (M, K) x (K, N) shapes; inputs are zero-padded up to the tile
    grid and the result is sliced back.  Zero padding is exact for matmul.
    """
    if x.ndim != 2 or w.ndim != 2 or x.shape[1] != w.shape[0]:
        raise ValueError(f"matmul shape mismatch: {x.shape} @ {w.shape}")
    return _matmul(x, w, block_m, block_n, block_k)
