"""Layer-1 Pallas kernels for Rudder.

Every kernel here runs under ``interpret=True`` (the CPU PJRT plugin cannot
execute real-TPU Mosaic custom-calls; see DESIGN.md §3).  Each kernel has a
pure-jnp oracle in :mod:`compile.kernels.ref` and a pytest/hypothesis sweep in
``python/tests/test_kernels.py``.
"""

from compile.kernels.matmul import matmul
from compile.kernels.sage_agg import sage_layer
from compile.kernels.score import score_update

__all__ = ["matmul", "sage_layer", "score_update"]
