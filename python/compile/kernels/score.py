"""Persistent-buffer score-update Pallas kernel (the Fig 4 policy).

Rudder's scoring policy (paper §2.1): an accessed item's frequency score is
incremented by 1; an item not accessed during the current minibatch-sampling
epoch is penalised by x0.95; scores falling below 0.95 mark the node "stale"
(evictable).  The buffer holds up to pct x |halo| scores per trainer, so the
update is a pure elementwise streaming op -- VPU work, one (block,) tile per
grid step, arithmetic intensity ~2 flops/float so the kernel is bandwidth
bound; the only optimisation that matters is a contiguous layout (the Rust
buffer keeps scores as a dense SoA column for exactly this reason).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DECAY = 0.95
STALE_THRESHOLD = 0.95


def _score_kernel(s_ref, a_ref, o_ref, stale_ref):
    s = s_ref[...]
    accessed = a_ref[...] > 0.0
    new = jnp.where(accessed, s + 1.0, s * DECAY)
    o_ref[...] = new
    stale_ref[...] = jnp.where(new < STALE_THRESHOLD, 1.0, 0.0)


def score_update(
    scores: jax.Array, accessed: jax.Array, *, block: int = 4096
) -> tuple[jax.Array, jax.Array]:
    """Apply one epoch of the scoring policy.

    Args:
      scores:   (N,) f32 current frequency scores.
      accessed: (N,) f32 0/1 mask -- was the slot touched this minibatch.
      block:    tile width.

    Returns:
      (new_scores, stale_mask) -- stale_mask[i] == 1.0 where the slot became
      evictable (score < 0.95).
    """
    if scores.shape != accessed.shape or scores.ndim != 1:
        raise ValueError(f"bad shapes: {scores.shape} vs {accessed.shape}")
    n = scores.shape[0]
    blk = min(block, n)
    pad = (-n) % blk
    if pad:
        scores = jnp.pad(scores, (0, pad), constant_values=1.0)
        accessed = jnp.pad(accessed, (0, pad), constant_values=1.0)
    np_ = scores.shape[0]
    new, stale = pl.pallas_call(
        _score_kernel,
        grid=(np_ // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_,), jnp.float32),
            jax.ShapeDtypeStruct((np_,), jnp.float32),
        ],
        interpret=True,
    )(scores.astype(jnp.float32), accessed.astype(jnp.float32))
    return new[:n], stale[:n]
