"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

These are the reference semantics the kernels are tested against in
``python/tests/test_kernels.py`` (hypothesis sweeps shapes/dtypes and
asserts allclose) and mirrored bit-for-bit by the Rust fallback compute
model in ``rust/src/gnn/``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

DECAY = 0.95
STALE_THRESHOLD = 0.95


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.matmul(
        x.astype(jnp.float32), w.astype(jnp.float32)
    ).astype(x.dtype)


def sage_layer_ref(
    x_self: jax.Array,
    x_neigh: jax.Array,
    w_self: jax.Array,
    w_neigh: jax.Array,
    bias: jax.Array,
    *,
    relu: bool = True,
) -> jax.Array:
    agg = jnp.mean(x_neigh.astype(jnp.float32), axis=1)
    h = (
        x_self.astype(jnp.float32) @ w_self.astype(jnp.float32)
        + agg @ w_neigh.astype(jnp.float32)
        + bias.astype(jnp.float32)
    )
    if relu:
        h = jnp.maximum(h, 0.0)
    return h.astype(x_self.dtype)


def score_update_ref(
    scores: jax.Array, accessed: jax.Array
) -> tuple[jax.Array, jax.Array]:
    s = scores.astype(jnp.float32)
    acc = accessed.astype(jnp.float32) > 0.0
    new = jnp.where(acc, s + 1.0, s * DECAY)
    stale = jnp.where(new < STALE_THRESHOLD, 1.0, 0.0)
    return new, stale
