"""Fused GraphSAGE aggregate+project Pallas kernel.

One GraphSAGE-mean layer over a *padded, dense* sampled neighborhood:

    out[b, :] = act( self[b] @ w_self  +  mean_k(neigh[b, k]) @ w_neigh + bias )

This is Rudder's compute hot-spot (the per-minibatch GNN step that the
prefetcher overlaps with).  TPU mapping: instead of porting the CUDA
gather-then-GEMM pattern, the neighbor-mean *reduction is fused into the
projection kernel* -- the grid walks batch tiles; each step holds a
(bb, D) self tile, a (bb, K, D) neighbor tile and both (D, H) weight panels
in VMEM, performs the mean on the VPU, then two MXU matmuls, so the
aggregated activations never round-trip to HBM.  VMEM per step with the
default bb=64, K=10, D=100, H=128: 64*100 + 64*10*100 + 2*100*128 + 64*128
floats = ~0.46 MiB @ f32, well inside the 16 MiB budget (and ~30x the
arithmetic intensity of the unfused version).

The kernel is forward-only: :func:`sage_layer` wraps it in ``jax.custom_vjp``
with a pure-jnp backward (the standard flash-attention-style pattern), so the
L2 train step can ``jax.grad`` through it and still lower to one HLO module.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sage_kernel(self_ref, neigh_ref, ws_ref, wn_ref, b_ref, o_ref, *, relu: bool):
    x_self = self_ref[...].astype(jnp.float32)        # (bb, D)
    x_neigh = neigh_ref[...].astype(jnp.float32)      # (bb, K, D)
    agg = jnp.mean(x_neigh, axis=1)                   # VPU reduction, stays in VMEM
    h = (
        jnp.dot(x_self, ws_ref[...], preferred_element_type=jnp.float32)
        + jnp.dot(agg, wn_ref[...], preferred_element_type=jnp.float32)
        + b_ref[...]
    )
    if relu:
        h = jnp.maximum(h, 0.0)
    o_ref[...] = h.astype(o_ref.dtype)


def _sage_fwd_pallas(
    x_self: jax.Array,   # (B, D)
    x_neigh: jax.Array,  # (B, K, D)
    w_self: jax.Array,   # (D, H)
    w_neigh: jax.Array,  # (D, H)
    bias: jax.Array,     # (H,)
    *,
    relu: bool,
    block_b: int = 64,
) -> jax.Array:
    b, d = x_self.shape
    _, k, _ = x_neigh.shape
    h = w_self.shape[1]
    bb = min(block_b, b)
    pad = (-b) % bb
    if pad:
        x_self = jnp.pad(x_self, ((0, pad), (0, 0)))
        x_neigh = jnp.pad(x_neigh, ((0, pad), (0, 0), (0, 0)))
    bp = x_self.shape[0]
    out = pl.pallas_call(
        functools.partial(_sage_kernel, relu=relu),
        grid=(bp // bb,),
        in_specs=[
            pl.BlockSpec((bb, d), lambda i: (i, 0)),
            pl.BlockSpec((bb, k, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((d, h), lambda i: (0, 0)),
            pl.BlockSpec((d, h), lambda i: (0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bb, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, h), x_self.dtype),
        interpret=True,
    )(x_self, x_neigh, w_self, w_neigh, bias)
    return out[:b]


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _sage_layer(x_self, x_neigh, w_self, w_neigh, bias, relu):
    return _sage_fwd_pallas(x_self, x_neigh, w_self, w_neigh, bias, relu=relu)


def _sage_layer_fwd(x_self, x_neigh, w_self, w_neigh, bias, relu):
    out = _sage_fwd_pallas(x_self, x_neigh, w_self, w_neigh, bias, relu=relu)
    return out, (x_self, x_neigh, w_self, w_neigh, out)


def _sage_layer_bwd(relu, res, g):
    x_self, x_neigh, w_self, w_neigh, out = res
    g = g.astype(jnp.float32)
    if relu:
        g = jnp.where(out > 0, g, 0.0)
    agg = jnp.mean(x_neigh.astype(jnp.float32), axis=1)
    d_bias = jnp.sum(g, axis=0)
    d_w_self = x_self.astype(jnp.float32).T @ g
    d_w_neigh = agg.T @ g
    d_x_self = g @ w_self.astype(jnp.float32).T
    d_agg = g @ w_neigh.astype(jnp.float32).T              # (B, D)
    k = x_neigh.shape[1]
    d_x_neigh = jnp.broadcast_to(d_agg[:, None, :] / k, x_neigh.shape)
    return (
        d_x_self.astype(x_self.dtype),
        d_x_neigh.astype(x_neigh.dtype),
        d_w_self.astype(w_self.dtype),
        d_w_neigh.astype(w_neigh.dtype),
        d_bias.astype(x_self.dtype),
    )


_sage_layer.defvjp(_sage_layer_fwd, _sage_layer_bwd)


def sage_layer(
    x_self: jax.Array,
    x_neigh: jax.Array,
    w_self: jax.Array,
    w_neigh: jax.Array,
    bias: jax.Array,
    *,
    relu: bool = True,
) -> jax.Array:
    """Differentiable fused GraphSAGE-mean layer (Pallas fwd, jnp bwd)."""
    if x_self.ndim != 2 or x_neigh.ndim != 3:
        raise ValueError(f"bad ranks: self {x_self.shape}, neigh {x_neigh.shape}")
    if x_self.shape[0] != x_neigh.shape[0] or x_self.shape[1] != x_neigh.shape[2]:
        raise ValueError(f"shape mismatch: self {x_self.shape}, neigh {x_neigh.shape}")
    return _sage_layer(x_self, x_neigh, w_self, w_neigh, bias, relu)
