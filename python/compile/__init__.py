"""Rudder build-time python package: L1 Pallas kernels + L2 JAX models,
AOT-lowered to HLO text by compile.aot. Never imported at runtime."""
