"""Layer-2: JAX compute graphs for Rudder, calling the L1 Pallas kernels.

Two model families, both AOT-lowered to HLO text by :mod:`compile.aot` and
executed from the Rust coordinator via PJRT:

* **GraphSAGE** -- the paper's GNN workload (2-layer mean-aggregator, fanout
  {10, 25}, node classification).  The distributed sampler (Rust, L3) hands
  each trainer a *padded dense* 2-hop sample; the train step here is the
  T_DDP hot loop the prefetcher overlaps with.
* **MLP decision classifier** -- one of Rudder's ML-classifier controllers
  (§4.4).  Inference and the online-finetune step (decision head update) are
  exported so the L3 inference daemon can run them through XLA.

Everything is pure-functional over flat parameter tuples so the HLO
signature is stable and the Rust side can pack literals positionally.
Parameters are donated in the train steps (no aliasing surprises: the AOT
module returns the new parameters as outputs).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from compile.kernels.matmul import matmul
from compile.kernels.sage_agg import sage_layer

# ---------------------------------------------------------------------------
# GraphSAGE


class SageParams(NamedTuple):
    """2-layer GraphSAGE parameters (flat, positional order is the ABI)."""

    w1_self: jax.Array   # (D, H)
    w1_neigh: jax.Array  # (D, H)
    b1: jax.Array        # (H,)
    w2_self: jax.Array   # (H, C)
    w2_neigh: jax.Array  # (H, C)
    b2: jax.Array        # (C,)


def sage_init(key: jax.Array, d: int, h: int, c: int) -> SageParams:
    """Glorot-ish init, deterministic in the key."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s1 = jnp.sqrt(2.0 / (d + h))
    s2 = jnp.sqrt(2.0 / (h + c))
    return SageParams(
        w1_self=jax.random.normal(k1, (d, h), jnp.float32) * s1,
        w1_neigh=jax.random.normal(k2, (d, h), jnp.float32) * s1,
        b1=jnp.zeros((h,), jnp.float32),
        w2_self=jax.random.normal(k3, (h, c), jnp.float32) * s2,
        w2_neigh=jax.random.normal(k4, (h, c), jnp.float32) * s2,
        b2=jnp.zeros((c,), jnp.float32),
    )


def sage_forward(
    params: SageParams,
    x_self: jax.Array,  # (B, D)   features of target nodes
    x_h1: jax.Array,    # (B, K1, D)  hop-1 neighbor features
    x_h2: jax.Array,    # (B, K1, K2, D)  hop-2 neighbor features
) -> jax.Array:
    """Two fused SAGE layers -> logits (B, C)."""
    b, k1, k2, d = x_h2.shape
    h = params.w1_self.shape[1]
    # Layer 1 on the hop-1 frontier: each hop-1 node aggregates its K2 sample.
    h1_frontier = sage_layer(
        x_h1.reshape(b * k1, d),
        x_h2.reshape(b * k1, k2, d),
        params.w1_self,
        params.w1_neigh,
        params.b1,
        relu=True,
    ).reshape(b, k1, h)
    # Layer 1 on the targets: aggregate the hop-1 sample.
    h1_self = sage_layer(
        x_self, x_h1, params.w1_self, params.w1_neigh, params.b1, relu=True
    )
    # Layer 2: targets aggregate their (now hidden-space) hop-1 frontier.
    return sage_layer(
        h1_self,
        h1_frontier,
        params.w2_self,
        params.w2_neigh,
        params.b2,
        relu=False,
    )


def sage_loss(
    params: SageParams,
    x_self: jax.Array,
    x_h1: jax.Array,
    x_h2: jax.Array,
    labels: jax.Array,  # (B,) int32
    mask: jax.Array,    # (B,) f32 -- 0 for padding rows
) -> jax.Array:
    """Masked mean softmax cross-entropy."""
    logits = sage_forward(params, x_self, x_h1, x_h2)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32), axis=1)[:, 0]
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(nll * mask) / denom


def sage_train_step(
    params: SageParams,
    x_self: jax.Array,
    x_h1: jax.Array,
    x_h2: jax.Array,
    labels: jax.Array,
    mask: jax.Array,
    lr: jax.Array,  # scalar f32
) -> tuple[SageParams, jax.Array]:
    """One SGD step; returns (new_params, loss).  fwd+bwd+update fused in HLO."""
    loss, grads = jax.value_and_grad(sage_loss)(
        params, x_self, x_h1, x_h2, labels, mask
    )
    new = SageParams(*(p - lr * g for p, g in zip(params, grads)))
    return new, loss


# ---------------------------------------------------------------------------
# MLP decision classifier (binary replace / skip)


class MlpParams(NamedTuple):
    w1: jax.Array  # (F, HM)
    b1: jax.Array  # (HM,)
    w2: jax.Array  # (HM, 2)
    b2: jax.Array  # (2,)


def mlp_init(key: jax.Array, f: int, hm: int) -> MlpParams:
    k1, k2 = jax.random.split(key)
    return MlpParams(
        w1=jax.random.normal(k1, (f, hm), jnp.float32) * jnp.sqrt(2.0 / f),
        b1=jnp.zeros((hm,), jnp.float32),
        w2=jax.random.normal(k2, (hm, 2), jnp.float32) * jnp.sqrt(2.0 / hm),
        b2=jnp.zeros((2,), jnp.float32),
    )


def mlp_forward(params: MlpParams, feats: jax.Array) -> jax.Array:
    """(B, F) -> logits (B, 2), hidden matmuls through the Pallas kernel."""
    h = jnp.maximum(matmul(feats, params.w1) + params.b1, 0.0)
    return matmul(h, params.w2) + params.b2


def mlp_infer(params: MlpParams, feats: jax.Array) -> jax.Array:
    """(B, F) -> replace-probability (B,)."""
    return jax.nn.softmax(mlp_forward(params, feats), axis=-1)[:, 1]


def _mlp_loss(params: MlpParams, feats: jax.Array, labels: jax.Array) -> jax.Array:
    logits = mlp_forward(params, feats)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32), axis=1)[:, 0]
    return jnp.mean(nll)


def mlp_train_step(
    params: MlpParams, feats: jax.Array, labels: jax.Array, lr: jax.Array
) -> tuple[MlpParams, jax.Array]:
    """One SGD step on the decision head (used by online finetuning)."""
    loss, grads = jax.value_and_grad(_mlp_loss)(params, feats, labels)
    new = MlpParams(*(p - lr * g for p, g in zip(params, grads)))
    return new, loss
