"""AOT pipeline: lower every L2 entry point to HLO *text* + a JSON manifest.

Run once by ``make artifacts`` (python is never on the request path):

    cd python && python -m compile.aot --out ../artifacts

Interchange format is HLO **text**, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Every lowered module is described in ``manifest.json`` -- input/output names,
shapes and dtypes in positional order -- which is the ABI the Rust runtime
(``rust/src/runtime/artifacts.rs``) packs literals against.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels.score import score_update

# Canonical artifact shapes.  The Rust sampler pads every minibatch to these;
# see DESIGN.md §2 (datasets use their own feature dims for *communication*
# accounting, compute runs through this canonical module).
DEFAULTS = dict(
    batch=128,      # B  target nodes per minibatch (padded)
    fanout1=10,     # K1 hop-1 fanout   (paper: fanout {10, 25})
    fanout2=25,     # K2 hop-2 fanout
    feat_dim=100,   # D  products-like feature width
    hidden=128,     # H
    classes=32,     # C  community pseudo-label space
    mlp_feats=12,   # F  decision-classifier feature vector (classifier/features.rs)
    mlp_hidden=32,  # HM
    mlp_batch=64,   # finetune minibatch
    score_block=4096,
)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple for stable ABI)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _desc(name, spec):
    return {"name": name, "shape": list(spec.shape), "dtype": str(spec.dtype)}


def build_entries(cfg: dict) -> dict[str, dict]:
    """entry name -> {fn, in_specs: [(name, spec)...], out_names: [...]}"""
    b, k1, k2 = cfg["batch"], cfg["fanout1"], cfg["fanout2"]
    d, h, c = cfg["feat_dim"], cfg["hidden"], cfg["classes"]
    f, hm, mb = cfg["mlp_feats"], cfg["mlp_hidden"], cfg["mlp_batch"]
    sb = cfg["score_block"]

    sage_params = [
        ("w1_self", _spec((d, h))),
        ("w1_neigh", _spec((d, h))),
        ("b1", _spec((h,))),
        ("w2_self", _spec((h, c))),
        ("w2_neigh", _spec((h, c))),
        ("b2", _spec((c,))),
    ]
    sage_batch = [
        ("x_self", _spec((b, d))),
        ("x_h1", _spec((b, k1, d))),
        ("x_h2", _spec((b, k1, k2, d))),
    ]
    mlp_params = [
        ("w1", _spec((f, hm))),
        ("b1", _spec((hm,))),
        ("w2", _spec((hm, 2))),
        ("b2", _spec((2,))),
    ]

    def sage_train_fn(*args):
        p = model.SageParams(*args[:6])
        new, loss = model.sage_train_step(p, *args[6:])
        return (*new, loss)

    def sage_fwd_fn(*args):
        p = model.SageParams(*args[:6])
        return (model.sage_forward(p, *args[6:]),)

    def mlp_infer_fn(*args):
        p = model.MlpParams(*args[:4])
        return (model.mlp_infer(p, args[4]),)

    def mlp_train_fn(*args):
        p = model.MlpParams(*args[:4])
        new, loss = model.mlp_train_step(p, *args[4:])
        return (*new, loss)

    def score_fn(scores, accessed):
        new, stale = score_update(scores, accessed, block=sb)
        return (new, stale)

    return {
        "sage_train_step": dict(
            fn=sage_train_fn,
            inputs=sage_params
            + sage_batch
            + [
                ("labels", _spec((b,), jnp.int32)),
                ("mask", _spec((b,))),
                ("lr", _spec(())),
            ],
            outputs=[f"new_{n}" for n, _ in sage_params] + ["loss"],
        ),
        "sage_fwd": dict(
            fn=sage_fwd_fn,
            inputs=sage_params + sage_batch,
            outputs=["logits"],
        ),
        "mlp_infer": dict(
            fn=mlp_infer_fn,
            inputs=mlp_params + [("feats", _spec((1, f)))],
            outputs=["replace_prob"],
        ),
        "mlp_train_step": dict(
            fn=mlp_train_fn,
            inputs=mlp_params
            + [
                ("feats", _spec((mb, f))),
                ("labels", _spec((mb,), jnp.int32)),
                ("lr", _spec(())),
            ],
            outputs=[f"new_{n}" for n, _ in mlp_params] + ["loss"],
        ),
        "score_update": dict(
            fn=score_fn,
            inputs=[("scores", _spec((sb,))), ("accessed", _spec((sb,)))],
            outputs=["new_scores", "stale_mask"],
        ),
    }


def lower_entry(name: str, entry: dict) -> str:
    specs = [s for _, s in entry["inputs"]]
    lowered = jax.jit(entry["fn"]).lower(*specs)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    for key, val in DEFAULTS.items():
        ap.add_argument(f"--{key}", type=int, default=val)
    ap.add_argument("--only", default=None, help="comma-separated entry names")
    args = ap.parse_args()
    cfg = {k: getattr(args, k) for k in DEFAULTS}

    os.makedirs(args.out, exist_ok=True)
    entries = build_entries(cfg)
    wanted = set(args.only.split(",")) if args.only else set(entries)
    manifest = {"config": cfg, "entries": {}}
    for name, entry in entries.items():
        if name not in wanted:
            continue
        text = lower_entry(name, entry)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as fh:
            fh.write(text)
        manifest["entries"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [_desc(n, s) for n, s in entry["inputs"]],
            "outputs": entry["outputs"],
        }
        print(f"  {name}: {len(text)} chars -> {path}")
    with open(os.path.join(args.out, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=2)
    print(f"wrote manifest with {len(manifest['entries'])} entries")


if __name__ == "__main__":
    main()
