"""L2 correctness: GraphSAGE + MLP classifier compute graphs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

jax.config.update("jax_platform_name", "cpu")

B, K1, K2, D, H, C = 8, 3, 4, 10, 16, 6


def _batch(seed=0):
    rng = np.random.default_rng(seed)
    return (
        jnp.asarray(rng.standard_normal((B, D)), jnp.float32),
        jnp.asarray(rng.standard_normal((B, K1, D)), jnp.float32),
        jnp.asarray(rng.standard_normal((B, K1, K2, D)), jnp.float32),
        jnp.asarray(rng.integers(0, C, B), jnp.int32),
        jnp.ones((B,), jnp.float32),
    )


@pytest.fixture(scope="module")
def params():
    return model.sage_init(jax.random.PRNGKey(0), D, H, C)


def test_forward_shape(params):
    x_self, x_h1, x_h2, _, _ = _batch()
    logits = model.sage_forward(params, x_self, x_h1, x_h2)
    assert logits.shape == (B, C)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_loss_positive_and_finite(params):
    loss = model.sage_loss(params, *_batch())
    assert np.isfinite(float(loss)) and float(loss) > 0.0


def test_mask_excludes_padding(params):
    x_self, x_h1, x_h2, labels, _ = _batch()
    mask_half = jnp.asarray([1.0] * (B // 2) + [0.0] * (B // 2))
    # Corrupt the masked-out labels; loss must not change.
    labels_bad = labels.at[B // 2 :].set((labels[B // 2 :] + 1) % C)
    l1 = model.sage_loss(params, x_self, x_h1, x_h2, labels, mask_half)
    l2 = model.sage_loss(params, x_self, x_h1, x_h2, labels_bad, mask_half)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def test_train_step_decreases_loss(params):
    batch = _batch(7)
    p = params
    lr = jnp.asarray(0.05, jnp.float32)
    first = float(model.sage_loss(p, *batch))
    for _ in range(30):
        p, loss = model.sage_train_step(p, *batch, lr)
    assert float(loss) < first * 0.7, (first, float(loss))


def test_train_step_grad_matches_numerical(params):
    # Spot-check d(loss)/d(b2) against central differences.
    batch = _batch(3)
    eps = 1e-3
    grads = jax.grad(model.sage_loss)(params, *batch)
    idx = 2
    bumped = params._replace(b2=params.b2.at[idx].add(eps))
    dipped = params._replace(b2=params.b2.at[idx].add(-eps))
    num = (float(model.sage_loss(bumped, *batch)) - float(model.sage_loss(dipped, *batch))) / (
        2 * eps
    )
    np.testing.assert_allclose(float(grads.b2[idx]), num, rtol=5e-2, atol=1e-4)


def test_train_step_zero_lr_is_identity(params):
    batch = _batch(5)
    new, _ = model.sage_train_step(params, *batch, jnp.asarray(0.0))
    for a, b in zip(new, params):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_init_deterministic():
    a = model.sage_init(jax.random.PRNGKey(42), D, H, C)
    b = model.sage_init(jax.random.PRNGKey(42), D, H, C)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# MLP classifier


def test_mlp_learns_linearly_separable():
    f, hm, n = 6, 16, 256
    rng = np.random.default_rng(0)
    x = rng.standard_normal((n, f)).astype(np.float32)
    y = (x[:, 0] + 0.5 * x[:, 1] > 0).astype(np.int32)
    p = model.mlp_init(jax.random.PRNGKey(1), f, hm)
    lr = jnp.asarray(0.5, jnp.float32)
    for _ in range(150):
        p, loss = model.mlp_train_step(p, jnp.asarray(x), jnp.asarray(y), lr)
    probs = np.asarray(model.mlp_infer(p, jnp.asarray(x)))
    acc = float(np.mean((probs > 0.5) == (y == 1)))
    assert acc > 0.95, acc


def test_mlp_infer_is_probability():
    p = model.mlp_init(jax.random.PRNGKey(2), 4, 8)
    x = jnp.asarray(np.random.default_rng(1).standard_normal((10, 4)), jnp.float32)
    probs = np.asarray(model.mlp_infer(p, x))
    assert probs.shape == (10,)
    assert np.all((probs >= 0.0) & (probs <= 1.0))


def test_mlp_train_reduces_loss():
    f, hm = 5, 8
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((64, f)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 2, 64), jnp.int32)
    p = model.mlp_init(jax.random.PRNGKey(3), f, hm)
    _, l0 = model.mlp_train_step(p, x, y, jnp.asarray(0.0))
    for _ in range(60):
        p, loss = model.mlp_train_step(p, x, y, jnp.asarray(0.3))
    assert float(loss) < float(l0)
