"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes/dtypes/block sizes; assert_allclose against ref.py.
This is the core correctness signal for the compute layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.matmul import matmul
from compile.kernels.sage_agg import sage_layer
from compile.kernels.score import score_update

jax.config.update("jax_platform_name", "cpu")

DIMS = st.integers(min_value=1, max_value=96)
BLOCKS = st.sampled_from([8, 16, 32, 128])


def _rand(key, shape, dtype=np.float32, scale=1.0):
    rng = np.random.default_rng(key)
    return (rng.standard_normal(shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# matmul


@settings(max_examples=25, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, bm=BLOCKS, bn=BLOCKS, bk=BLOCKS, seed=st.integers(0, 2**31))
def test_matmul_matches_ref_shapes(m, k, n, bm, bn, bk, seed):
    x = _rand(seed, (m, k))
    w = _rand(seed + 1, (k, n))
    got = matmul(jnp.asarray(x), jnp.asarray(w), block_m=bm, block_n=bn, block_k=bk)
    want = ref.matmul_ref(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-4), (jnp.bfloat16, 8e-2)])
def test_matmul_dtypes(dtype, tol):
    x = jnp.asarray(_rand(7, (33, 17))).astype(dtype)
    w = jnp.asarray(_rand(8, (17, 29))).astype(dtype)
    got = np.asarray(matmul(x, w), dtype=np.float32)
    want = np.asarray(ref.matmul_ref(x, w), dtype=np.float32)
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_matmul_identity():
    x = jnp.asarray(_rand(3, (40, 40)))
    np.testing.assert_allclose(
        np.asarray(matmul(x, jnp.eye(40))), np.asarray(x), rtol=1e-5, atol=1e-5
    )


def test_matmul_zero():
    x = jnp.asarray(_rand(4, (12, 8)))
    out = matmul(x, jnp.zeros((8, 5)))
    assert np.all(np.asarray(out) == 0.0)


def test_matmul_rejects_bad_shapes():
    with pytest.raises(ValueError):
        matmul(jnp.zeros((3, 4)), jnp.zeros((5, 6)))
    with pytest.raises(ValueError):
        matmul(jnp.zeros((3,)), jnp.zeros((3, 2)))


def test_matmul_grad_matches_ref():
    x = jnp.asarray(_rand(11, (9, 7)))
    w = jnp.asarray(_rand(12, (7, 5)))
    g_x = jax.grad(lambda a: matmul(a, w).sum())(x)
    g_x_ref = jax.grad(lambda a: ref.matmul_ref(a, w).sum())(x)
    np.testing.assert_allclose(np.asarray(g_x), np.asarray(g_x_ref), rtol=1e-4, atol=1e-4)
    g_w = jax.grad(lambda b: (matmul(x, b) ** 2).sum())(w)
    g_w_ref = jax.grad(lambda b: (ref.matmul_ref(x, b) ** 2).sum())(w)
    np.testing.assert_allclose(np.asarray(g_w), np.asarray(g_w_ref), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# sage_layer


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 70),
    k=st.integers(1, 12),
    d=st.integers(1, 40),
    h=st.integers(1, 40),
    relu=st.booleans(),
    seed=st.integers(0, 2**31),
)
def test_sage_layer_matches_ref(b, k, d, h, relu, seed):
    xs = jnp.asarray(_rand(seed, (b, d)))
    xn = jnp.asarray(_rand(seed + 1, (b, k, d)))
    ws = jnp.asarray(_rand(seed + 2, (d, h)))
    wn = jnp.asarray(_rand(seed + 3, (d, h)))
    bias = jnp.asarray(_rand(seed + 4, (h,)))
    got = sage_layer(xs, xn, ws, wn, bias, relu=relu)
    want = ref.sage_layer_ref(xs, xn, ws, wn, bias, relu=relu)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_sage_layer_grads_match_ref():
    b, k, d, h = 13, 4, 9, 6
    xs = jnp.asarray(_rand(1, (b, d)))
    xn = jnp.asarray(_rand(2, (b, k, d)))
    ws = jnp.asarray(_rand(3, (d, h)))
    wn = jnp.asarray(_rand(4, (d, h)))
    bias = jnp.asarray(_rand(5, (h,)))

    def loss(fn):
        def inner(args):
            return (fn(*args) ** 2).sum()

        return inner

    args = (xs, xn, ws, wn, bias)
    g = jax.grad(loss(lambda *a: sage_layer(*a)))(args)
    g_ref = jax.grad(loss(lambda *a: ref.sage_layer_ref(*a)))(args)
    for gi, gr in zip(g, g_ref):
        np.testing.assert_allclose(np.asarray(gi), np.asarray(gr), rtol=1e-3, atol=1e-3)


def test_sage_layer_relu_clamps():
    xs = -10.0 * jnp.ones((4, 3))
    xn = -10.0 * jnp.ones((4, 2, 3))
    ws = jnp.eye(3)
    wn = jnp.eye(3)
    bias = jnp.zeros((3,))
    out = sage_layer(xs, xn, ws, wn, bias, relu=True)
    assert np.all(np.asarray(out) == 0.0)


def test_sage_layer_mean_aggregation():
    # With w_self = 0 and w_neigh = I the output is exactly the neighbor mean.
    b, k, d = 5, 3, 4
    xn = jnp.asarray(_rand(9, (b, k, d)))
    out = sage_layer(
        jnp.zeros((b, d)), xn, jnp.zeros((d, d)), jnp.eye(d), jnp.zeros((d,)), relu=False
    )
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(jnp.mean(xn, axis=1)), rtol=1e-5, atol=1e-5
    )


def test_sage_layer_rejects_bad_shapes():
    with pytest.raises(ValueError):
        sage_layer(
            jnp.zeros((4, 3)), jnp.zeros((5, 2, 3)), jnp.zeros((3, 2)),
            jnp.zeros((3, 2)), jnp.zeros((2,)),
        )
    with pytest.raises(ValueError):
        sage_layer(
            jnp.zeros((4, 3)), jnp.zeros((4, 2, 7)), jnp.zeros((3, 2)),
            jnp.zeros((3, 2)), jnp.zeros((2,)),
        )


# ---------------------------------------------------------------------------
# score_update


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 300),
    block=st.sampled_from([1, 7, 64, 4096]),
    seed=st.integers(0, 2**31),
)
def test_score_update_matches_ref(n, block, seed):
    rng = np.random.default_rng(seed)
    scores = (rng.random(n) * 4).astype(np.float32)
    accessed = (rng.random(n) > 0.5).astype(np.float32)
    got_s, got_m = score_update(jnp.asarray(scores), jnp.asarray(accessed), block=block)
    want_s, want_m = ref.score_update_ref(jnp.asarray(scores), jnp.asarray(accessed))
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(got_m), np.asarray(want_m))


def test_score_update_semantics():
    scores = jnp.asarray([1.0, 1.0, 0.99, 10.0], dtype=jnp.float32)
    accessed = jnp.asarray([1.0, 0.0, 0.0, 0.0], dtype=jnp.float32)
    new, stale = score_update(scores, accessed, block=4)
    np.testing.assert_allclose(np.asarray(new), [2.0, 0.95, 0.9405, 9.5], rtol=1e-6)
    # 0.95 is NOT < 0.95, so slot 1 survives; slot 2 fell below.
    np.testing.assert_array_equal(np.asarray(stale), [0.0, 0.0, 1.0, 0.0])


def test_score_update_never_accessed_decays_to_stale():
    s = jnp.ones((1,), jnp.float32)
    a = jnp.zeros((1,), jnp.float32)
    steps = 0
    while steps < 10:
        s, stale = score_update(s, a, block=1)
        steps += 1
        if np.asarray(stale)[0] == 1.0:
            break
    # 1.0 * 0.95 = 0.95 (not stale); 0.95 * 0.95 = 0.9025 < 0.95 -> stale at 2.
    assert steps == 2


def test_score_update_rejects_shape_mismatch():
    with pytest.raises(ValueError):
        score_update(jnp.zeros((3,)), jnp.zeros((4,)))
