"""pytest package for the Rudder compile path."""
