"""AOT pipeline checks: lowering, manifest ABI, HLO properties.

Uses tiny shape overrides so the full pipeline runs in seconds.
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")

TINY = dict(
    aot.DEFAULTS,
    batch=4, fanout1=2, fanout2=3, feat_dim=5, hidden=6, classes=3,
    mlp_feats=4, mlp_hidden=5, mlp_batch=8, score_block=16,
)


@pytest.fixture(scope="module")
def entries():
    return aot.build_entries(TINY)


def test_all_five_entries_present(entries):
    assert set(entries) == {
        "sage_train_step", "sage_fwd", "mlp_infer", "mlp_train_step", "score_update",
    }


@pytest.mark.parametrize(
    "name",
    ["sage_train_step", "sage_fwd", "mlp_infer", "mlp_train_step", "score_update"],
)
def test_entry_lowers_to_hlo_text(entries, name):
    text = aot.lower_entry(name, entries[name])
    assert "HloModule" in text
    # interpret=True pallas must lower to plain HLO: no Mosaic custom-calls.
    assert "custom-call" not in text or "mosaic" not in text.lower()


def test_manifest_abi_matches_execution(entries):
    """Executing the jitted fn with manifest-shaped zeros yields outputs
    matching the declared output arity -- the contract the Rust runtime
    relies on."""
    for name, entry in entries.items():
        args = [
            jnp.zeros(tuple(s.shape), s.dtype) for _, s in entry["inputs"]
        ]
        out = jax.jit(entry["fn"])(*args)
        assert len(out) == len(entry["outputs"]), name


def test_train_step_abi_roundtrip(entries):
    """new-params outputs have identical shapes to the param inputs."""
    entry = entries["sage_train_step"]
    in_shapes = {n: s.shape for n, s in entry["inputs"]}
    args = [jnp.zeros(tuple(s.shape), s.dtype) for _, s in entry["inputs"]]
    out = jax.jit(entry["fn"])(*args)
    for i, out_name in enumerate(entry["outputs"][:-1]):  # last is loss
        pname = out_name.removeprefix("new_")
        assert out[i].shape == in_shapes[pname]
    assert out[-1].shape == ()


def test_cli_writes_artifacts_and_manifest(tmp_path):
    cmd = [
        sys.executable, "-m", "compile.aot", "--out", str(tmp_path),
        "--batch", "4", "--fanout1", "2", "--fanout2", "3", "--feat_dim", "5",
        "--hidden", "6", "--classes", "3", "--mlp_feats", "4",
        "--mlp_hidden", "5", "--mlp_batch", "8", "--score_block", "16",
        "--only", "score_update,mlp_infer",
    ]
    env = dict(os.environ, PYTHONPATH=os.path.dirname(os.path.dirname(__file__)))
    subprocess.run(cmd, check=True, cwd=os.path.dirname(os.path.dirname(__file__)), env=env)
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert set(manifest["entries"]) == {"score_update", "mlp_infer"}
    for e in manifest["entries"].values():
        assert (tmp_path / e["file"]).exists()
        for inp in e["inputs"]:
            assert inp["dtype"] in ("float32", "int32")


def test_checked_in_manifest_consistent_if_present():
    """If `make artifacts` has run, the manifest must describe real files
    whose HLO entry computation matches the recorded config shapes."""
    art = os.path.join(os.path.dirname(os.path.dirname(os.path.dirname(__file__))), "artifacts")
    mpath = os.path.join(art, "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts not built")
    manifest = json.loads(open(mpath).read())
    cfg = manifest["config"]
    for name, e in manifest["entries"].items():
        text = open(os.path.join(art, e["file"])).read()
        assert "HloModule" in text
    b, d = cfg["batch"], cfg["feat_dim"]
    sage = manifest["entries"]["sage_train_step"]
    x_self = next(i for i in sage["inputs"] if i["name"] == "x_self")
    assert x_self["shape"] == [b, d]
