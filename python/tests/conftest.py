"""Collection guards: skip cleanly when optional heavy deps are absent.

CI runs `python -m pytest python/tests` as a non-blocking job; on machines
without JAX (or hypothesis for the kernel sweeps) the suite must skip, not
error at import time.
"""

import importlib.util

collect_ignore = []

if importlib.util.find_spec("jax") is None:
    # Every module imports jax at the top level.
    collect_ignore += ["test_kernels.py", "test_model.py", "test_aot.py"]
elif importlib.util.find_spec("hypothesis") is None:
    # Only the kernel sweeps need hypothesis.
    collect_ignore += ["test_kernels.py"]
