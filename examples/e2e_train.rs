//! End-to-end driver: REAL GraphSAGE training through the full stack.
//!
//! Proves all layers compose: the Rust coordinator samples minibatches
//! from a partitioned graph, Rudder's agent steers the persistent buffer,
//! and every train step executes the AOT `sage_train_step` entry through
//! the runtime engine — the pure-Rust interpreter by default, or the
//! PJRT-compiled HLO (L2 JAX + L1 Pallas kernels) with `--features pjrt`
//! plus built artifacts (`python -m compile.aot`).  Logs the loss curve
//! and eval accuracy.
//!
//! ```bash
//! cargo run --release --example e2e_train          # interpreter backend
//! E2E_STEPS=40 cargo run --release --example e2e_train   # shorter run
//! ```

use std::sync::Arc;

use rudder::eval::report::fmt_secs;
use rudder::gnn::SageRunner;
use rudder::runtime::Engine;
use rudder::sim::{build_cluster, ControllerSpec, RunConfig};
use rudder::sim::{run_on, Mode};

fn main() -> rudder::error::Result<()> {
    let Some(engine) = Engine::try_load_default() else {
        rudder::bail!(
            "requested artifacts are unusable — fix or remove ./artifacts (or \
             $RUDDER_ARTIFACTS), or rebuild them with `python -m compile.aot`"
        );
    };
    let engine = Arc::new(engine);
    let art = engine.manifest.config.clone();
    println!(
        "runtime backend: {}; artifact shapes: batch={} fanout=({},{}) D={} H={} C={}",
        engine.platform(), art.batch, art.fanout1, art.fanout2, art.feat_dim,
        art.hidden, art.classes
    );

    // The artifact bakes the minibatch shape, so the run must match it.
    let steps_target = std::env::var("E2E_STEPS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .unwrap_or(120);
    let cfg = RunConfig {
        dataset: "ogbn-arxiv".into(),
        scale: 0.5,
        num_trainers: 2,
        batch_size: art.batch,
        fanout1: art.fanout1,
        fanout2: art.fanout2,
        buffer_pct: 0.25,
        epochs: 1,
        controller: ControllerSpec::parse("llm:gemma3-4b")?,
        mode: Mode::Async,
        ..Default::default()
    };
    let (ds, part) = build_cluster(&cfg)?;
    println!(
        "dataset: {} — {} nodes / {} edges, {} train nodes, {} trainers\n",
        cfg.dataset,
        ds.csr.num_nodes(),
        ds.csr.num_arcs() / 2,
        ds.train_nodes.len(),
        cfg.num_trainers
    );

    // --- Phase 1: real XLA training loop with Rudder prefetching ---------
    // One trainer runs measured (real PJRT steps); we drive it manually so
    // the loss curve is logged step by step.
    let mut runner = SageRunner::new(engine.clone(), 7, 0.05);
    let sampler = rudder::sampler::Sampler::new(
        0, art.batch, art.fanout1, art.fanout2, 1234,
    );
    let train0 = part.train_nodes_of(0, &ds.train_nodes);
    let mut buffer = rudder::buffer::PersistentBuffer::new(
        (part.halo_k(&ds.csr, 0, 2).len() as f64 * cfg.buffer_pct) as usize,
        rudder::buffer::scoring::Policy::FreqDecay,
    );
    let mut steps = 0usize;
    let mut epoch = 0usize;
    let t_start = std::time::Instant::now();
    let mut wall_compute = 0.0;
    println!("step  epoch  loss     hits%   step_ms");
    'outer: loop {
        let order = sampler.epoch_order(&train0, epoch);
        let mbs = sampler.minibatches_per_epoch(train0.len());
        for mb in 0..mbs {
            let b = sampler.sample(&ds.csr, &part, &order, epoch, mb);
            if b.targets.is_empty() {
                continue;
            }
            let lookup = buffer.lookup(&b.unique_remote);
            let (loss, dt) = runner.train_step(&b, ds.feature_seed, &ds.labels)?;
            wall_compute += dt;
            // Simple adaptive cadence: refresh whenever stale inventory
            // accumulates (the agent decision path is exercised in phase 2).
            buffer.end_round();
            if buffer.len() < buffer.capacity()
                || buffer.stale_count() > buffer.capacity() / 10
            {
                buffer.replace();
            }
            steps += 1;
            if steps % 10 == 0 || steps == 1 {
                println!(
                    "{:<5} {:<6} {:<8.4} {:<7.1} {:<7.1}",
                    steps,
                    epoch,
                    loss,
                    lookup.hits_pct(),
                    dt * 1e3
                );
            }
            if steps >= steps_target {
                break 'outer;
            }
        }
        epoch += 1;
    }
    let first_losses = &runner.losses[..10.min(runner.losses.len())];
    let last_losses = &runner.losses[runner.losses.len().saturating_sub(10)..];
    let first = first_losses.iter().sum::<f32>() / first_losses.len() as f32;
    let last = last_losses.iter().sum::<f32>() / last_losses.len() as f32;
    println!(
        "\n{} real runtime steps in {} (compute {}), loss {:.4} -> {:.4} ({:.1}% drop)",
        steps,
        fmt_secs(t_start.elapsed().as_secs_f64()),
        fmt_secs(wall_compute),
        first,
        last,
        (1.0 - last / first) * 100.0
    );
    rudder::ensure!(last < first, "loss must decrease over the run");

    // Eval accuracy on a held-out sample.
    let eval_order = sampler.epoch_order(&train0, 999);
    let eval_mb = sampler.sample(&ds.csr, &part, &eval_order, 999, 0);
    let acc = runner.eval_accuracy(&eval_mb, ds.feature_seed, &ds.labels)?;
    println!("train-sample accuracy: {:.1}% (chance {:.1}%)", acc * 100.0,
             100.0 / art.classes as f64);

    // --- Phase 2: the full simulated cluster for the same workload -------
    println!("\nfull-cluster simulation of the same config:");
    let r = run_on(&ds, &part, &cfg, None);
    println!(
        "  {}: epoch {}, steady hits {:.1}%, comm {} nodes",
        r.label,
        fmt_secs(r.mean_epoch_time),
        r.steady_hits_pct,
        r.total_comm_nodes
    );
    println!("\ne2e OK — all layers composed (results in EXPERIMENTS.md §E2E)");
    Ok(())
}
