//! Out-of-distribution study (§5.4): classifiers pretrained on *seen*
//! datasets vs the zero-shot LLM agent, on the unseen yelp / ogbn-arxiv
//! stand-ins, with and without online finetuning.
//!
//! ```bash
//! cargo run --release --example unseen_adaptation
//! ```

use rudder::eval::harness::offline_training_set;
use rudder::eval::report::{fmt_pct, fmt_secs, Table};
use rudder::eval::Quality;
use rudder::sim::{build_cluster, run_on, ControllerSpec, RunConfig};

fn main() -> rudder::error::Result<()> {
    println!("pretraining classifiers on SEEN datasets (products traces)...");
    let offline = offline_training_set(Quality::Quick);
    println!("  {} labelled examples (positive rate {:.2})\n", offline.len(),
             offline.positive_rate());

    let mut t = Table::new(
        "Unseen-dataset adaptation (paper §5.4, Figs 18/19)",
        &["dataset", "controller", "epoch_time", "steady_hits", "verdict"],
    );
    for dataset in ["yelp", "ogbn-arxiv"] {
        let cfg0 = RunConfig {
            dataset: dataset.into(),
            scale: 0.25,
            num_trainers: 4,
            buffer_pct: 0.25,
            epochs: 8,
            ..Default::default()
        };
        let (ds, part) = build_cluster(&cfg0)?;
        let mut rows = Vec::new();
        for spec in [
            "llm:gemma3-4b",
            "clf:mlp",
            "clf:mlp:finetune=25",
            "clf:tabnet",
            "clf:tabnet:finetune=25",
        ] {
            let mut cfg = cfg0.clone();
            cfg.controller = ControllerSpec::parse(spec)?;
            let r = run_on(&ds, &part, &cfg, Some(&offline));
            rows.push((r.label.clone(), r.mean_epoch_time, r.steady_hits_pct));
        }
        let llm_hits = rows[0].2;
        for (label, time, hits) in rows {
            let verdict = if label.contains("gemma") {
                "zero-shot (Corollary 2.2)".to_string()
            } else if hits + 1.0 < llm_hits {
                format!("shifted: {:.1} pts below LLM", llm_hits - hits)
            } else {
                "matches LLM".to_string()
            };
            t.row(vec![
                dataset.to_string(),
                label,
                fmt_secs(time),
                fmt_pct(hits),
                verdict,
            ]);
        }
    }
    println!("{}", t.render());
    Ok(())
}
