//! Cluster runtime demo: real threads, wire-format RPC, and the
//! prefetch/compute overlap the virtual-time sim can only model.
//!
//! Runs the same small job three ways — no prefetch, fixed replacement,
//! LLM-agent-steered — on the in-process cluster runtime with emulated
//! net/compute costs, then verifies traffic parity against the sim.
//!
//! ```bash
//! cargo run --release --example cluster_overlap
//! ```

use std::sync::Arc;

use rudder::cluster::{parity_check, run_cluster_on, ClusterConfig, ComputeMode};
use rudder::eval::report::{fmt_count, fmt_pct, fmt_secs, Table};
use rudder::sim::{build_cluster, run_on, ControllerSpec, RunConfig};

fn main() -> rudder::error::Result<()> {
    let base = RunConfig {
        dataset: "ogbn-arxiv".into(),
        scale: 0.15,
        num_trainers: 2,
        buffer_pct: 0.25,
        epochs: 2,
        ..Default::default()
    };
    println!(
        "cluster overlap demo: {} (scale {}), {} trainers, {} epochs\n",
        base.dataset, base.scale, base.num_trainers, base.epochs
    );
    let (ds, part) = build_cluster(&base)?;
    let ds = Arc::new(ds);
    let part = Arc::new(part);

    let mut table = Table::new(
        "cluster runtime: prefetch off vs on (wall-clock, emulated costs)",
        &["variant", "wall/epoch", "virtual/epoch", "steady_hits", "wire_bytes_in", "deduped"],
    );
    for spec in ["none", "fixed", "llm:gemma3-4b"] {
        let mut cfg = base.clone();
        cfg.controller = ControllerSpec::parse(spec)?;
        let mut ccfg = ClusterConfig::new(cfg.clone());
        ccfg.compute = ComputeMode::Emulated(0.02);
        let r = run_cluster_on(ds.clone(), part.clone(), &ccfg, None)?;
        // Every variant stays counter-identical to the virtual-time sim.
        let sim_r = run_on(ds.as_ref(), part.as_ref(), &cfg, None);
        parity_check(&sim_r, &r.experiment)
            .map_err(|e| rudder::err!("traffic parity broken for {spec}: {e}"))?;
        let wire = r.wire_total();
        table.row(vec![
            r.experiment.label.clone(),
            fmt_secs(r.mean_epoch_wall()),
            fmt_secs(r.experiment.mean_epoch_time),
            fmt_pct(r.experiment.steady_hits_pct),
            fmt_count(wire.resp_bytes),
            fmt_count(wire.nodes_deduped),
        ]);
    }
    println!("{}", table.render());
    println!("(traffic parity vs the virtual-time sim verified for every variant)");
    Ok(())
}
