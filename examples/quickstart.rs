//! Quickstart: the three §5 variants side by side on one small graph.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use rudder::eval::report::{fmt_count, fmt_pct, fmt_secs, Table};
use rudder::sim::{build_cluster, run_on, ControllerSpec, RunConfig};

fn main() -> rudder::error::Result<()> {
    let mut cfg = RunConfig {
        dataset: "products".into(),
        scale: 0.2,
        num_trainers: 4,
        buffer_pct: 0.25,
        epochs: 6,
        ..Default::default()
    };
    println!(
        "quickstart: {} (scale {}), {} trainers, {:.0}% buffer, {} epochs\n",
        cfg.dataset, cfg.scale, cfg.num_trainers, cfg.buffer_pct * 100.0, cfg.epochs
    );
    let (ds, part) = build_cluster(&cfg)?;
    println!(
        "graph: {} nodes / {} edges; edge cut {:.1}%\n",
        ds.csr.num_nodes(),
        ds.csr.num_arcs() / 2,
        part.edge_cut(&ds.csr) as f64 / (ds.csr.num_arcs() / 2) as f64 * 100.0
    );

    let mut table = Table::new(
        "DistDGL vs DistDGL+fixed vs DistDGL+Rudder",
        &["variant", "epoch_time", "steady_hits", "comm_nodes", "comm_reduction"],
    );
    let mut base_comm = None;
    for spec in ["none", "fixed", "llm:gemma3-4b"] {
        cfg.controller = ControllerSpec::parse(spec)?;
        let r = run_on(&ds, &part, &cfg, None);
        let comm = r.total_comm_nodes;
        let reduction = base_comm
            .map(|b: u64| format!("{:.1}%", (1.0 - comm as f64 / b as f64) * 100.0))
            .unwrap_or_else(|| "-".into());
        if base_comm.is_none() {
            base_comm = Some(comm);
        }
        table.row(vec![
            r.label.clone(),
            fmt_secs(r.mean_epoch_time),
            fmt_pct(r.steady_hits_pct),
            fmt_count(comm),
            reduction,
        ]);
    }
    println!("{}", table.render());
    println!("(see `rudder experiment all` for the full paper reproduction)");
    Ok(())
}
