//! Agent comparison: every LLM profile and classifier head to head on one
//! dataset (a compact version of Tables 2/4).
//!
//! ```bash
//! cargo run --release --example agent_comparison [dataset]
//! ```

use rudder::agent::profiles;
use rudder::classifier::ALL_KINDS;
use rudder::eval::harness::offline_training_set;
use rudder::eval::report::{fmt_count, fmt_pct, fmt_secs, Table};
use rudder::eval::{pass_at_1, Quality};
use rudder::sim::{build_cluster, run_on, ControllerSpec, RunConfig};

fn main() -> rudder::error::Result<()> {
    let dataset = std::env::args().nth(1).unwrap_or_else(|| "products".into());
    let cfg0 = RunConfig {
        dataset: dataset.clone(),
        scale: 0.25,
        num_trainers: 4,
        buffer_pct: 0.25,
        epochs: 8,
        ..Default::default()
    };
    let (ds, part) = build_cluster(&cfg0)?;
    println!("agent comparison on {dataset} ({} nodes)\n", ds.csr.num_nodes());

    println!("collecting offline traces for classifier pretraining...");
    let offline = offline_training_set(Quality::Quick);
    println!("  {} labelled examples\n", offline.len());

    let mut t = Table::new(
        &format!("LLM agents vs ML classifiers — {dataset}"),
        &["controller", "epoch_time", "steady_hits", "comm", "r", "valid%", "pass@1"],
    );
    let mut specs: Vec<String> = profiles::ALL
        .iter()
        .map(|p| format!("llm:{}", p.name))
        .collect();
    specs.extend(ALL_KINDS.iter().map(|k| format!("clf:{}", k.name().to_lowercase())));
    for spec in specs {
        let mut cfg = cfg0.clone();
        cfg.controller = ControllerSpec::parse(&spec)?;
        let r = run_on(&ds, &part, &cfg, Some(&offline));
        let p = pass_at_1(&r.per_trainer);
        t.row(vec![
            r.label.clone(),
            fmt_secs(r.mean_epoch_time),
            fmt_pct(r.steady_hits_pct),
            fmt_count(r.total_comm_nodes),
            format!("{:.0}", r.replacement_interval),
            format!("{:.0}", r.valid_response_pct),
            if p.trials > 0 { p.format() } else { "-".into() },
        ]);
    }
    println!("{}", t.render());
    Ok(())
}
