//! Offline stand-in for the `xla` crate (xla-rs PJRT bindings).
//!
//! Mirrors exactly the API surface `rudder`'s PJRT backend consumes:
//! [`Literal`] packing/unpacking works for real (host buffers), while the
//! device-side entry points ([`PjRtClient::cpu`],
//! [`HloModuleProto::from_text_file`], compile/execute) return
//! [`Error::Unavailable`] so the `--features pjrt` build type-checks and
//! fails loudly — not mysteriously — at runtime.  Swap in the real crate
//! with a `[patch]` entry to get actual PJRT execution.

use std::fmt;
use std::path::Path;

/// Stub error: either a host-side usage error or "no PJRT linked".
#[derive(Debug)]
pub enum Error {
    Unavailable(&'static str),
    Invalid(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "xla-stub: {what} requires the real PJRT runtime; this build links the \
                 offline shim (swap in xla-rs via [patch] — see README.md)"
            ),
            Error::Invalid(msg) => write!(f, "xla-stub: {msg}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element dtypes the Rudder artifacts use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    fn size(self) -> usize {
        4
    }
}

/// Types a [`Literal`] can be unpacked into.
pub trait NativeType: Copy {
    const ELEMENT_TYPE: ElementType;
    fn from_le_bytes(b: [u8; 4]) -> Self;
}

impl NativeType for f32 {
    const ELEMENT_TYPE: ElementType = ElementType::F32;
    fn from_le_bytes(b: [u8; 4]) -> Self {
        f32::from_le_bytes(b)
    }
}

impl NativeType for i32 {
    const ELEMENT_TYPE: ElementType = ElementType::S32;
    fn from_le_bytes(b: [u8; 4]) -> Self {
        i32::from_le_bytes(b)
    }
}

/// A host-side tensor literal (shape + raw bytes).  Fully functional in the
/// stub — only device transfer/execution is unavailable.
#[derive(Debug, Clone)]
pub struct Literal {
    element_type: ElementType,
    dims: Vec<usize>,
    data: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        element_type: ElementType,
        dims: &[usize],
        untyped_data: &[u8],
    ) -> Result<Literal> {
        let n: usize = dims.iter().product();
        if untyped_data.len() != n * element_type.size() {
            return Err(Error::Invalid(format!(
                "literal: {} bytes for shape {dims:?} (want {})",
                untyped_data.len(),
                n * element_type.size()
            )));
        }
        Ok(Literal {
            element_type,
            dims: dims.to_vec(),
            data: untyped_data.to_vec(),
        })
    }

    pub fn element_type(&self) -> ElementType {
        self.element_type
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty() && self.dims.iter().product::<usize>() == 0
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.element_type != T::ELEMENT_TYPE {
            return Err(Error::Invalid(format!(
                "literal: dtype mismatch ({:?} vs requested {:?})",
                self.element_type,
                T::ELEMENT_TYPE
            )));
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| T::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::Unavailable("tuple decomposition"))
    }
}

/// Parsed HLO module (text interchange format).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(Error::Unavailable("HLO text parsing"))
    }
}

/// An XLA computation handle.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A device buffer returned by execution.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable("device-to-host transfer"))
    }
}

/// A compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("execution"))
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Unavailable("PJRT CPU client"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("compilation"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_works_hostside() {
        let data: Vec<f32> = vec![1.0, -2.5, 3.0, 0.0, 7.5, 9.0];
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 3], &bytes)
                .unwrap();
        assert_eq!(lit.dims(), &[2, 3]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[4], &[0u8; 8])
                .is_err()
        );
    }

    #[test]
    fn device_paths_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent.hlo.txt").is_err());
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("PJRT"));
    }
}
